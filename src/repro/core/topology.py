"""CXL.mem topology model (paper §2, Figure 1).

A topology is a tree: a CXL Root Complex (RC) at the root, CXL switches as
internal nodes, and memory pools (expanders) as leaves.  Local DRAM is pool 0
and hangs directly off the memory controller (empty switch path).  Every
component is annotated with the paper's three quantities:

  * ``latency_ns``  — added round-trip latency of traversing the component,
  * ``bandwidth_gbps`` — sustained bandwidth (GB/s) through the component,
  * ``stt_ns``      — serial transmission time: minimum spacing between two
                      transactions through the same component (switches only).

``FlatTopology`` lowers the tree to dense arrays so the timing analyzer
(:mod:`repro.core.analyzer`) can be vectorized / jitted.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Pool",
    "Switch",
    "Topology",
    "FlatTopology",
    "figure1_topology",
    "local_only_topology",
    "two_tier_topology",
]


@dataclasses.dataclass(frozen=True)
class Switch:
    """A CXL switch (or the Root Complex, which behaves like one)."""

    name: str
    latency_ns: float  # added latency per transaction through this switch
    bandwidth_gbps: float  # GB/s through the switch
    stt_ns: float  # serial transmission time (min gap between transactions)
    parent: Optional[str] = None  # parent switch name; None => attached to RC


@dataclasses.dataclass(frozen=True)
class Pool:
    """A memory pool / expander (leaf of the topology tree)."""

    name: str
    latency_ns: float  # device media latency (round trip, added)
    bandwidth_gbps: float  # device-side bandwidth
    capacity_bytes: int
    parent: Optional[str] = None  # switch it hangs off; None => direct to RC
    is_local: bool = False  # True only for local DRAM


class Topology:
    """A validated CXL.mem topology tree.

    Construction order does not matter; ``validate()`` checks the tree is
    acyclic, parents exist, and there is exactly one local DRAM pool.
    """

    def __init__(
        self,
        pools: Sequence[Pool],
        switches: Sequence[Switch] = (),
        rc_latency_ns: float = 10.0,
        rc_bandwidth_gbps: float = 256.0,
        rc_stt_ns: float = 0.5,
        local_dram_latency_ns: float = 88.9,  # paper's measured platform latency
    ):
        self.pools: List[Pool] = list(pools)
        self.switches: List[Switch] = list(switches)
        self.rc_latency_ns = float(rc_latency_ns)
        self.rc_bandwidth_gbps = float(rc_bandwidth_gbps)
        self.rc_stt_ns = float(rc_stt_ns)
        self.local_dram_latency_ns = float(local_dram_latency_ns)
        self._switch_by_name: Dict[str, Switch] = {s.name: s for s in self.switches}
        self._pool_index: Dict[str, int] = {p.name: i for i, p in enumerate(self.pools)}
        self.validate()

    # ------------------------------------------------------------------ #

    def validate(self) -> None:
        if len({p.name for p in self.pools}) != len(self.pools):
            raise ValueError("duplicate pool names")
        if len(self._switch_by_name) != len(self.switches):
            raise ValueError("duplicate switch names")
        locals_ = [p for p in self.pools if p.is_local]
        if len(locals_) != 1:
            raise ValueError(f"need exactly one local DRAM pool, got {len(locals_)}")
        if self.pools.index(locals_[0]) != 0:
            raise ValueError("local DRAM must be pool index 0")
        if locals_[0].parent is not None:
            raise ValueError("local DRAM must attach directly (parent=None)")
        for s in self.switches:
            if s.parent is not None and s.parent not in self._switch_by_name:
                raise ValueError(f"switch {s.name}: unknown parent {s.parent}")
        for p in self.pools:
            if p.parent is not None and p.parent not in self._switch_by_name:
                raise ValueError(f"pool {p.name}: unknown parent {p.parent}")
        # acyclicity: walk each switch to the RC with a step bound
        for s in self.switches:
            seen = set()
            cur: Optional[str] = s.name
            while cur is not None:
                if cur in seen:
                    raise ValueError(f"cycle through switch {cur}")
                seen.add(cur)
                cur = self._switch_by_name[cur].parent

    # ------------------------------------------------------------------ #

    def pool_index(self, name: str) -> int:
        return self._pool_index[name]

    def switch_path(self, pool: Pool) -> List[Switch]:
        """Switches traversed from the pool up to (not including) the RC."""
        path: List[Switch] = []
        cur = pool.parent
        while cur is not None:
            sw = self._switch_by_name[cur]
            path.append(sw)
            cur = sw.parent
        return path

    def pool_total_latency_ns(self, pool: Pool) -> float:
        """End-to-end added latency of one access to ``pool``.

        Local DRAM: its media latency only.  Remote pools: media latency +
        every switch on the path + the RC.
        """
        if pool.is_local:
            return pool.latency_ns
        lat = pool.latency_ns + self.rc_latency_ns
        for sw in self.switch_path(pool):
            lat += sw.latency_ns
        return lat

    def pool_path_bandwidth_gbps(self, pool: Pool) -> float:
        """Min bandwidth along the path (bottleneck link)."""
        bw = pool.bandwidth_gbps
        if not pool.is_local:
            bw = min(bw, self.rc_bandwidth_gbps)
            for sw in self.switch_path(pool):
                bw = min(bw, sw.bandwidth_gbps)
        return bw

    def flatten(self) -> "FlatTopology":
        return FlatTopology.from_topology(self)

    def describe(self) -> str:
        lines = [
            f"Topology: {len(self.pools)} pools, {len(self.switches)} switches "
            f"(RC lat={self.rc_latency_ns}ns bw={self.rc_bandwidth_gbps}GB/s "
            f"stt={self.rc_stt_ns}ns; local DRAM lat={self.local_dram_latency_ns}ns)"
        ]
        for p in self.pools:
            path = " -> ".join(s.name for s in self.switch_path(p)) or "(direct)"
            lines.append(
                f"  pool[{self.pool_index(p.name)}] {p.name}: lat={p.latency_ns}ns "
                f"bw={p.bandwidth_gbps}GB/s cap={p.capacity_bytes / 2**30:.1f}GiB "
                f"path={path} total_lat={self.pool_total_latency_ns(p):.1f}ns"
            )
        for s in self.switches:
            lines.append(
                f"  switch {s.name}: lat={s.latency_ns}ns bw={s.bandwidth_gbps}GB/s "
                f"stt={s.stt_ns}ns parent={s.parent or 'RC'}"
            )
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class FlatTopology:
    """Dense-array lowering of a :class:`Topology` for the analyzer.

    Switch index S-1 is always the RC (remote accesses traverse it); switch
    arrays therefore have ``n_switches + 1`` entries.
    """

    n_pools: int
    n_switches: int  # including the RC pseudo-switch (last index)
    pool_latency_ns: np.ndarray  # [P] total added latency per access
    pool_bandwidth_gbps: np.ndarray  # [P] bottleneck bandwidth on path
    pool_capacity: np.ndarray  # [P] bytes
    local_latency_ns: float
    # route[P, S] == 1 iff accesses to pool P traverse switch S
    route: np.ndarray
    switch_stt_ns: np.ndarray  # [S]
    switch_bandwidth_gbps: np.ndarray  # [S]
    # depth of each switch in the tree (RC = 0, children of RC = 1, ...).
    # The analyzer cascades serial queues deepest-first so an event's shift at
    # a leaf switch is visible when it merges at its parent — matching the
    # event-by-event fine-grained simulator.
    switch_depth: np.ndarray
    pool_names: Tuple[str, ...]
    switch_names: Tuple[str, ...]

    def stage_order(self) -> np.ndarray:
        """Switch indices ordered deepest-first (RC last)."""
        return np.argsort(-self.switch_depth, kind="stable")

    @staticmethod
    def from_topology(t: Topology) -> "FlatTopology":
        P = len(t.pools)
        S = len(t.switches) + 1  # + RC
        pool_lat = np.zeros((P,), np.float64)
        pool_bw = np.zeros((P,), np.float64)
        pool_cap = np.zeros((P,), np.float64)
        route = np.zeros((P, S), np.float64)
        sw_index = {s.name: i for i, s in enumerate(t.switches)}
        for i, p in enumerate(t.pools):
            pool_lat[i] = t.pool_total_latency_ns(p)
            pool_bw[i] = t.pool_path_bandwidth_gbps(p)
            pool_cap[i] = p.capacity_bytes
            if not p.is_local:
                route[i, S - 1] = 1.0  # RC
                for sw in t.switch_path(p):
                    route[i, sw_index[sw.name]] = 1.0
        stt = np.array([s.stt_ns for s in t.switches] + [t.rc_stt_ns], np.float64)
        sw_bw = np.array(
            [s.bandwidth_gbps for s in t.switches] + [t.rc_bandwidth_gbps], np.float64
        )

        def depth(sw: Switch) -> int:
            d = 1
            cur = sw.parent
            while cur is not None:
                d += 1
                cur = t._switch_by_name[cur].parent
            return d

        sw_depth = np.array([depth(s) for s in t.switches] + [0], np.int32)
        return FlatTopology(
            n_pools=P,
            n_switches=S,
            pool_latency_ns=pool_lat,
            pool_bandwidth_gbps=pool_bw,
            pool_capacity=pool_cap,
            local_latency_ns=t.local_dram_latency_ns,
            route=route,
            switch_stt_ns=stt,
            switch_bandwidth_gbps=sw_bw,
            switch_depth=sw_depth,
            pool_names=tuple(p.name for p in t.pools),
            switch_names=tuple(s.name for s in t.switches) + ("RC",),
        )


# --------------------------------------------------------------------------- #
# Canonical topologies
# --------------------------------------------------------------------------- #


def local_only_topology(capacity_gib: float = 96.0) -> Topology:
    """Degenerate topology: local DRAM only (native execution baseline)."""
    return Topology(
        pools=[
            Pool(
                "local_dram",
                latency_ns=88.9,
                bandwidth_gbps=76.8,  # DDR5-4800 dual channel
                capacity_bytes=int(capacity_gib * 2**30),
                is_local=True,
            )
        ]
    )


def figure1_topology() -> Topology:
    """The paper's Figure 1: two CXL switches, three memory pools.

    The figure annotates BW/Lat/STT per component; the published text embeds
    them in an image, so we use representative CXL 2.0 numbers (x8 PCIe 5.0
    links, ~70 ns switch traversal) consistent with the paper's prose.

        RC ── switch0 ── pool1 (near pool, direct expander)
              └─ switch1 ── pool2, pool3 (far pools behind 2nd-level switch)
    """
    return Topology(
        pools=[
            Pool("local_dram", 88.9, 76.8, int(96 * 2**30), is_local=True),
            Pool("cxl_pool1", 150.0, 32.0, int(128 * 2**30), parent="switch0"),
            Pool("cxl_pool2", 180.0, 32.0, int(256 * 2**30), parent="switch1"),
            Pool("cxl_pool3", 180.0, 32.0, int(256 * 2**30), parent="switch1"),
        ],
        switches=[
            Switch("switch0", latency_ns=70.0, bandwidth_gbps=64.0, stt_ns=2.0),
            Switch(
                "switch1",
                latency_ns=70.0,
                bandwidth_gbps=32.0,
                stt_ns=4.0,
                parent="switch0",
            ),
        ],
        rc_latency_ns=10.0,
        rc_bandwidth_gbps=128.0,
        rc_stt_ns=0.5,
    )


def two_tier_topology(
    cxl_latency_ns: float = 170.0,
    cxl_bandwidth_gbps: float = 32.0,
    cxl_capacity_gib: float = 512.0,
) -> Topology:
    """Simple two-tier topology: local DRAM + one direct CXL expander."""
    return Topology(
        pools=[
            Pool("local_dram", 88.9, 76.8, int(96 * 2**30), is_local=True),
            Pool(
                "cxl_pool",
                cxl_latency_ns,
                cxl_bandwidth_gbps,
                int(cxl_capacity_gib * 2**30),
                parent="sw",
            ),
        ],
        switches=[Switch("sw", latency_ns=70.0, bandwidth_gbps=cxl_bandwidth_gbps, stt_ns=2.0)],
    )
