"""CXLMemSim core — the paper's contribution as a composable JAX library.

Components (paper Figure 2):
  Tracer  -> :mod:`repro.core.tracer`   (+ :mod:`repro.core.events` region map)
  Timer   -> :mod:`repro.core.timer`
  Timing Analyzer -> :mod:`repro.core.analyzer` (epoch, JAX) and the
  fine-grained DES baseline (our Gem5 stand-in)
  Analysis engine -> :mod:`repro.core.engine` (shared async dispatcher:
  overlap + cross-session batching for every attached session)
  Topology -> :mod:`repro.core.topology`
  Research surfaces -> :mod:`repro.core.policy` (placement),
  :mod:`repro.core.migration` (sw/hw migration + prefetch),
  :mod:`repro.core.coherency` (multi-host pool sharing)
  Roofline -> :mod:`repro.core.roofline`
"""

from .analyzer import (
    DelayBreakdown,
    EpochAnalyzer,
    FineGrainedSimulator,
    analyze_ref,
    plan_cascade,
)
from .attach import AttachedProgram, CXLMemSim, SimReport
from .cache import DeviceCacheConfig, DeviceCacheModel
from .coherency import CoherencyConfig, CoherencyModel
from .engine import AnalysisEngine, EngineHandle
from .events import (
    CACHELINE_BYTES,
    PAGE_BYTES,
    EventStager,
    MemEvents,
    Region,
    RegionMap,
    concat_events,
    merge_host_traces,
    split_by_host,
    synthetic_trace,
)
from .fabric import FabricReport, FabricSession, HostClock, Tenant
from .fleet import (
    FleetPoint,
    FleetReport,
    FleetSim,
    TenantPlacement,
    TenantSpec,
    model_zoo_tenant,
    synthetic_tenant,
)
from .migration import LocalBudget, MigrationConfig, MigrationSimulator
from .policy import (
    ClassMapPolicy,
    HotnessTieredPolicy,
    InterleavePolicy,
    LocalOnlyPolicy,
    PlacementPolicy,
    RegionArrays,
    assign_batch,
    bytes_per_pool_batch,
    capacity_check,
)
from .roofline import RooflineTerms, collective_bytes_from_hlo, roofline_terms
from .scenario import Scenario, ScenarioSuite, SweepResult
from .timer import EpochSchedule, slice_by_quantum
from .topology import (
    FlatTopology,
    FlatTopologyStack,
    Pool,
    QosSpec,
    Switch,
    Topology,
    TopologyOverride,
    figure1_topology,
    flatten_stack,
    local_only_topology,
    pooled_topology,
    two_tier_topology,
)
from .tracer import (
    Access,
    HardwareModel,
    Phase,
    TPU_V5E,
    TraceSkeleton,
    hlo_cost_summary,
    skeleton_to_events,
    synthesize_skeleton,
    synthesize_step_trace,
)

__all__ = [
    "Access",
    "AnalysisEngine",
    "AttachedProgram",
    "CACHELINE_BYTES",
    "CXLMemSim",
    "EngineHandle",
    "ClassMapPolicy",
    "CoherencyConfig",
    "CoherencyModel",
    "DelayBreakdown",
    "DeviceCacheConfig",
    "DeviceCacheModel",
    "EpochAnalyzer",
    "EpochSchedule",
    "EventStager",
    "FabricReport",
    "FabricSession",
    "FineGrainedSimulator",
    "FlatTopology",
    "FlatTopologyStack",
    "FleetPoint",
    "FleetReport",
    "FleetSim",
    "HostClock",
    "HardwareModel",
    "HotnessTieredPolicy",
    "InterleavePolicy",
    "LocalBudget",
    "LocalOnlyPolicy",
    "MemEvents",
    "MigrationConfig",
    "MigrationSimulator",
    "PAGE_BYTES",
    "Phase",
    "PlacementPolicy",
    "Pool",
    "Region",
    "RegionArrays",
    "RegionMap",
    "RooflineTerms",
    "Scenario",
    "ScenarioSuite",
    "SimReport",
    "SweepResult",
    "QosSpec",
    "Switch",
    "TPU_V5E",
    "Tenant",
    "TenantPlacement",
    "TenantSpec",
    "Topology",
    "TopologyOverride",
    "TraceSkeleton",
    "analyze_ref",
    "assign_batch",
    "bytes_per_pool_batch",
    "capacity_check",
    "collective_bytes_from_hlo",
    "concat_events",
    "figure1_topology",
    "flatten_stack",
    "hlo_cost_summary",
    "local_only_topology",
    "merge_host_traces",
    "model_zoo_tenant",
    "plan_cascade",
    "pooled_topology",
    "roofline_terms",
    "skeleton_to_events",
    "slice_by_quantum",
    "split_by_host",
    "synthetic_tenant",
    "synthetic_trace",
    "synthesize_skeleton",
    "synthesize_step_trace",
    "two_tier_topology",
]
