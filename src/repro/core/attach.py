"""CXLMemSim.attach — the user-facing simulator (paper Figure 2, assembled).

Wraps any jitted step function.  Per step:

  1. cut the step's structural trace into epochs (Timer), apply migration
     remapping, inject coherency traffic, and run the device-cache tag
     simulation (stateful, main thread) — the cache's per-epoch hit
     fractions become latency-scale vectors shipped with the batch;
  2. submit the step's epoch batch to the Timing Analyzer — by default
     **asynchronously** through the shared
     :class:`~repro.core.engine.AnalysisEngine`: one process-wide
     dispatcher thread serves every attached session (depth-2 backpressure
     per session, cross-session coalescing into stacked dispatches), so
     the analyzer's device work overlaps the next step's native execution
     (the paper's low-overhead attach model);
  3. dispatch the real step and measure native wall time (the paper's
     "execution of the attached program");
  4. optionally ``time.sleep`` the computed delay — the paper's delay
     injection, making the host observe simulated-topology speed (this
     forces synchronous analysis: the delay must exist before it can be
     injected).

All epochs of a step go through :meth:`EpochAnalyzer.analyze_batch` as one
device dispatch; results cross the host boundary once per step, not once
per epoch.  Reading :attr:`AttachedProgram.report` flushes any in-flight
async work first, so observed totals are always consistent.  A batch lost
to an analyzer failure is *accounted*: the error is re-raised once from
``flush()`` and the report's ``dropped_batches`` / ``dropped_epochs``
record the truncation permanently.

Two clocks are reported:

  * ``native_s``    — measured host execution time,
  * ``simulated_s`` — native + Σ delays (what the topology would impose),

plus the per-component delay decomposition, per-pool/switch, per-epoch.
``analyzer_s`` stays the analyzer's own compute seconds (the paper's
overhead accounting) whether or not it overlapped native execution.

``AttachedProgram`` is a context manager; ``with sim.attach(...) as prog``
(or an explicit ``prog.close()``) releases its engine handle.  The shared
engine keeps exactly one dispatcher thread for the whole process — attach
cycles no longer park one worker thread each.

This module attaches **one** program to a private topology.  To co-attach
several programs on one shared fabric — cross-host contention at shared
switches, trace-driven coherency — use
:class:`repro.core.fabric.FabricSession`, which composes the same tracer /
timer / analyzer stack over a merged multi-host timeline (and overlaps its
rounds through the same shared engine).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..analysis.annotations import guarded_by
from .analyzer import DelayBreakdown, EpochAnalyzer, FineGrainedSimulator, analyze_any
from .cache import DeviceCacheConfig, DeviceCacheModel
from .coherency import CoherencyModel
from .engine import AnalysisEngine, EngineClient, EngineHandle, fold_dispatch_stats
from .events import MemEvents, RegionMap
from .migration import MigrationSimulator
from .policy import PlacementPolicy, capacity_check
from .timer import EpochSchedule
from .topology import Topology
from .tracer import HardwareModel, Phase, TPU_V5E, synthesize_step_trace
from .units import ns_to_s

__all__ = ["CXLMemSim", "AttachedProgram", "SimReport"]


@dataclasses.dataclass
class SimReport:
    steps: int = 0
    epochs: int = 0
    native_s: float = 0.0
    simulated_s: float = 0.0
    latency_s: float = 0.0
    congestion_s: float = 0.0
    bandwidth_s: float = 0.0
    coherency_s: float = 0.0
    injected_sleep_s: float = 0.0
    analyzer_s: float = 0.0  # simulator's own cost (overhead accounting)
    per_pool_latency_ns: Optional[np.ndarray] = None
    per_switch_congestion_ns: Optional[np.ndarray] = None
    per_switch_bandwidth_ns: Optional[np.ndarray] = None
    qos_classes: int = 1  # arbitration classes of the attached fabric
    per_class_congestion_ns: Optional[np.ndarray] = None  # [qos_classes]
    migration_moved_bytes: float = 0.0
    cache_hit_fraction: float = float("nan")  # device-cache running hit rate
    dropped_batches: int = 0  # analysis batches lost to analyzer failures
    dropped_epochs: int = 0  # their epochs: totals exclude exactly these
    # sharded-dispatch observability (maxima over this session's dispatches)
    devices_used: int = 1  # devices the stacked dispatch sharded over
    shard_rows: int = 0  # per-device rows of the padded leading axis (0=unsharded)
    padded_waste: float = 0.0  # worst padding fraction of the leading axis
    coalesced_group_size: int = 1  # sessions stacked into one dispatch
    # pipeline-phase timing (sums over this session's dispatches)
    stage_s: float = 0.0  # host staging-plane pack time
    transfer_s: float = 0.0  # explicit H2D device_put time
    compile_s: float = 0.0  # AOT lowering time (first dispatch per shape only)
    compute_s: float = 0.0  # exposed device compute (post-overlap)
    donated_dispatches: int = 0  # dispatches whose input planes were donated
    aot_cache_hits: int = 0  # dispatches served from the AOT executable cache

    @property
    def slowdown(self) -> float:
        """Simulated time / native time — the paper's headline metric."""
        return self.simulated_s / self.native_s if self.native_s > 0 else float("nan")

    @property
    def overhead(self) -> float:
        """(native + analyzer + injected) / native: host-side cost of simulating."""
        if self.native_s <= 0:
            return float("nan")
        return (self.native_s + self.analyzer_s + self.injected_sleep_s) / self.native_s

    def qos_delay_shares(self) -> List[float]:
        """Fraction of switch queueing delay charged to each QoS class."""
        pcc = self.per_class_congestion_ns
        if pcc is None:
            return [1.0]
        total = float(pcc.sum())
        if total <= 0.0:
            return [0.0] * len(pcc)
        return [float(x) / total for x in pcc]

    def summary(self) -> Dict[str, float]:
        """The full report contract — every scalar a benchmark JSON consumer
        needs, key set locked by ``tests/test_engine.py``."""
        return {
            "steps": self.steps,
            "epochs": self.epochs,
            "native_s": self.native_s,
            "simulated_s": self.simulated_s,
            "slowdown": self.slowdown,
            "latency_s": self.latency_s,
            "congestion_s": self.congestion_s,
            "bandwidth_s": self.bandwidth_s,
            "coherency_s": self.coherency_s,
            "injected_sleep_s": self.injected_sleep_s,
            "analyzer_s": self.analyzer_s,
            "overhead": self.overhead,
            "migration_moved_bytes": self.migration_moved_bytes,
            "cache_hit_fraction": self.cache_hit_fraction,
            "dropped_batches": self.dropped_batches,
            "dropped_epochs": self.dropped_epochs,
            "devices_used": self.devices_used,
            "shard_rows": self.shard_rows,
            "padded_waste": self.padded_waste,
            "coalesced_group_size": self.coalesced_group_size,
            "stage_s": self.stage_s,
            "transfer_s": self.transfer_s,
            "compile_s": self.compile_s,
            "compute_s": self.compute_s,
            "donated_dispatches": self.donated_dispatches,
            "aot_cache_hits": self.aot_cache_hits,
            "qos_classes": self.qos_classes,
            "qos_delay_shares": self.qos_delay_shares(),
        }


class CXLMemSim:
    """Configure once, attach to any number of step functions."""

    def __init__(
        self,
        topology: Topology,
        policy: PlacementPolicy,
        epoch: EpochSchedule = EpochSchedule("step"),
        hw: HardwareModel = TPU_V5E,
        inject_delays: bool = False,
        sample_rate: float = 1.0,
        migration: Optional[MigrationSimulator] = None,
        cache: Optional[DeviceCacheConfig] = None,
        coherency: Optional[CoherencyModel] = None,
        analyzer: str = "epoch",  # 'epoch' (paper) | 'fine' (Gem5-like baseline)
        n_windows: int = 128,
        check_capacity: bool = True,
        max_events_per_access: int = 64,  # trace fidelity (higher = finer)
        async_analysis: Optional[bool] = None,  # None: auto (see below)
        engine: Optional[AnalysisEngine] = None,  # None: the shared default
        pipeline: bool = False,  # device-resident epoch pipeline (AOT + donation)
        warmup: bool = False,  # pre-compile the pipeline executable at attach
    ):
        self.topology = topology
        self.flat = topology.flatten()
        self.policy = policy
        self.epoch = epoch
        self.hw = hw
        self.inject_delays = inject_delays
        self.sample_rate = sample_rate
        self.migration = migration
        self.cache = cache
        self.coherency = coherency
        self.analyzer_kind = analyzer
        self.n_windows = n_windows
        self.check_capacity = check_capacity
        self.max_events_per_access = max_events_per_access
        self.engine = engine
        self.pipeline = pipeline
        self.warmup = warmup
        # async analysis overlaps analyzer work with native execution; delay
        # injection needs the delay before the step returns, so it forces
        # the synchronous path
        if async_analysis is None:
            async_analysis = analyzer == "epoch" and not inject_delays
        self.async_analysis = bool(async_analysis) and not inject_delays

    def attach(
        self,
        step_fn: Callable[..., Any],
        phases: Sequence[Phase],
        regions: RegionMap,
        calibration: float = 1.0,
    ) -> "AttachedProgram":
        self.policy.place(regions, self.flat)
        if self.check_capacity:
            capacity_check(regions, self.flat)
        return AttachedProgram(self, step_fn, list(phases), regions, calibration)


class AttachedProgram(EngineClient):
    # the report is folded from the engine's dispatcher thread while the
    # submitting thread accumulates native clocks — every touch locks
    _simlint_guards = guarded_by("_report_lock", "_report")

    def __init__(
        self,
        sim: CXLMemSim,
        step_fn: Callable[..., Any],
        phases: List[Phase],
        regions: RegionMap,
        calibration: float,
    ):
        self.sim = sim
        self.step_fn = step_fn
        self.phases = phases
        self.regions = regions
        self.calibration = calibration
        if sim.analyzer_kind == "epoch":
            self._analyzer = EpochAnalyzer(
                sim.flat, n_windows=sim.n_windows, pipeline=sim.pipeline
            )
        else:
            self._analyzer = FineGrainedSimulator(sim.flat, bandwidth_mode="per_txn")
        self._cache = (
            DeviceCacheModel(sim.cache, sim.flat, [regions])
            if sim.cache is not None
            else None
        )
        self._report = SimReport(
            per_pool_latency_ns=np.zeros((sim.flat.n_pools,)),
            per_switch_congestion_ns=np.zeros((sim.flat.n_switches,)),
            per_switch_bandwidth_ns=np.zeros((sim.flat.n_switches,)),
            qos_classes=sim.flat.n_qos_classes,
            per_class_congestion_ns=np.zeros((sim.flat.n_qos_classes,)),
        )
        self._report_lock = threading.Lock()
        self._trace_cache: Optional[tuple] = None
        if sim.async_analysis:
            eng = sim.engine if sim.engine is not None else AnalysisEngine.default()
            self._handle: Optional[EngineHandle] = eng.register(self._analyzer)
        else:
            self._handle = None
        if sim.warmup and isinstance(self._analyzer, EpochAnalyzer):
            # pre-compile the pipeline executable on this step's trace shapes
            # so the first real dispatch is a pure AOT-cache hit
            traces, _, _ = self._traces()
            self._analyzer.warmup(traces)

    # ------------------------------------------------------------------ #

    @property
    def report(self) -> SimReport:
        """The accumulated report; flushes in-flight async analysis first
        (``flush``/``close``/context-manager semantics come from
        :class:`~repro.core.engine.EngineClient`)."""
        self.flush()
        return self._report  # simlint: ignore[lock-discipline] -- post-flush read: no in-flight fold can race the caller's view

    # ------------------------------------------------------------------ #

    def _traces(self):
        """Structural traces are shape-static per step; cache across steps,
        but recompute when migration has changed residency."""
        if self._trace_cache is None or self.sim.migration is not None:
            mode = "layer" if self.sim.epoch.mode == "layer" else "step"
            traces, native_ns, names = synthesize_step_trace(
                self.phases,
                self.regions,
                hw=self.sim.hw,
                granularity_bytes=self.sim.policy.granularity_bytes,
                max_events_per_access=self.sim.max_events_per_access,
                calibration=self.calibration,
                epoch_mode=mode,
            )
            if self.sim.epoch.mode == "quantum":
                cut: List[MemEvents] = []
                for tr in traces:
                    cut.extend(self.sim.epoch.slices(tr))
                traces = cut
                native_ns = [self.sim.epoch.quantum_ns] * len(traces)
                names = [f"q{i}" for i in range(len(traces))]
            if self.sim.sample_rate < 1.0:
                traces = [t.sample(self.sim.sample_rate, seed=i) for i, t in enumerate(traces)]
            self._trace_cache = (traces, native_ns, names)
        return self._trace_cache

    def _epoch_batch(self) -> Tuple[List[MemEvents], float, Optional[List]]:
        """One step's epoch traces with migration/coherency/cache applied.

        Stateful transforms run on the submitting thread so their epoch
        order is deterministic; only the (pure) analysis is offloaded.
        The device cache observes the *final* per-epoch stream (including
        injected migration and BI traffic, which warms and pollutes it like
        any other access) and returns per-epoch latency-scale vectors."""
        traces, _, _ = self._traces()
        from .events import concat_events  # local import to avoid cycle

        batch: List[MemEvents] = []
        scales: Optional[List] = [] if self._cache is not None else None
        coh_ns_total = 0.0
        for tr in traces:
            if self.sim.migration is not None:
                tr, extra = self.sim.migration.observe_and_migrate(tr)
                if extra.n:
                    tr = concat_events([tr, extra])
            if self.sim.coherency is not None:
                bi, coh_ns = self.sim.coherency.epoch_traffic(tr)
                coh_ns_total += coh_ns
                if bi.n:
                    tr = concat_events([tr, bi])
            if self._cache is not None:
                scales.append(self._cache.observe_scale(tr))
            batch.append(tr)
        if self.sim.migration is not None or self._cache is not None:
            # running-statistic snapshots; written under the report lock —
            # the async dispatcher folds breakdowns under the same lock
            with self._report_lock:
                if self.sim.migration is not None:
                    self._report.migration_moved_bytes = (
                        self.sim.migration.moved_bytes_total
                    )
                if self._cache is not None:
                    self._report.cache_hit_fraction = self._cache.hit_fraction
        return batch, coh_ns_total, scales

    def _fold(
        self, bd: DelayBreakdown, coh_ns: float, analyzer_s: float, n_epochs: int
    ) -> float:
        """Fold one analyzed batch into the report (any thread; locks).

        Returns the batch's total delay in ns.  ``analyzer_s`` accumulates
        the analyzer's own compute time regardless of overlap."""
        delay_ns = bd.total_ns + coh_ns
        with self._report_lock:
            r = self._report
            r.epochs += n_epochs
            r.latency_s += ns_to_s(bd.latency_ns)
            r.congestion_s += ns_to_s(bd.congestion_ns)
            r.bandwidth_s += ns_to_s(bd.bandwidth_ns)
            r.coherency_s += ns_to_s(coh_ns)
            r.per_pool_latency_ns += bd.per_pool_latency_ns
            r.per_switch_congestion_ns += bd.per_switch_congestion_ns
            r.per_switch_bandwidth_ns += bd.per_switch_bandwidth_ns
            if bd.per_class_congestion_ns is not None:
                pcc = np.asarray(bd.per_class_congestion_ns, np.float64)
                if len(pcc) == len(r.per_class_congestion_ns):
                    r.per_class_congestion_ns += pcc
                else:  # qos-off breakdown on a multi-class fabric: all class 0
                    r.per_class_congestion_ns[0] += float(pcc.sum())
            r.simulated_s += ns_to_s(delay_ns)
            r.analyzer_s += analyzer_s
            if self._handle is not None:
                fold_dispatch_stats(
                    r, self._handle.last_dispatch, self._handle.last_group_size
                )
            else:
                fold_dispatch_stats(
                    r, getattr(self._analyzer, "last_dispatch", None), 1
                )
        return delay_ns

    def _analyze_and_accumulate(
        self, batch: List[MemEvents], coh_ns: float, scales: Optional[List] = None
    ) -> float:
        """Synchronous path: analyze one step's epoch batch inline and fold
        it; returns the step's total delay in ns.  A failed batch is
        recorded as dropped before the error propagates, mirroring the
        async engine's accounting."""
        a0 = time.perf_counter()
        try:
            bd = analyze_any(self._analyzer, batch, scales)
        except BaseException:
            with self._report_lock:
                self._report.dropped_batches += 1
                self._report.dropped_epochs += len(batch)
            raise
        elapsed = time.perf_counter() - a0
        return self._fold(bd, coh_ns, elapsed, len(batch))

    def step(self, *args, **kwargs):
        """Run one real step under simulation; returns the step's outputs.

        In async mode the step's epoch batch is submitted *before* the
        native dispatch, so the analyzer works while the step executes;
        totals become visible via :attr:`report` (which flushes)."""
        batch, coh_ns, scales = self._epoch_batch()
        if self._handle is not None:
            n_epochs = len(batch)
            self._handle.submit(
                batch,
                scales,
                fold=lambda bd, elapsed: self._fold(bd, coh_ns, elapsed, n_epochs),
            )

        t0 = time.perf_counter()
        out = self.step_fn(*args, **kwargs)
        jax.block_until_ready(out)
        native = time.perf_counter() - t0
        with self._report_lock:
            self._report.native_s += native
            self._report.simulated_s += native
            self._report.steps += 1

        if self._handle is None:
            delay_ns = self._analyze_and_accumulate(batch, coh_ns, scales)
            if self.sim.inject_delays and delay_ns > 0:
                # the paper's delay injection: the host program observes the
                # simulated-topology execution speed
                time.sleep(ns_to_s(delay_ns))
                with self._report_lock:
                    self._report.injected_sleep_s += ns_to_s(delay_ns)
        return out

    def run(self, n_steps: int, *args, **kwargs) -> SimReport:
        for _ in range(n_steps):
            self.step(*args, **kwargs)
        self.flush()
        return self._report  # simlint: ignore[lock-discipline] -- post-flush read: no in-flight fold can race the caller's view
