"""CXLMemSim.attach — the user-facing simulator (paper Figure 2, assembled).

Wraps any jitted step function.  Per step:

  1. cut the step's structural trace into epochs (Timer), apply migration
     remapping, inject coherency traffic, and run the device-cache tag
     simulation (stateful, main thread) — the cache's per-epoch hit
     fractions become latency-scale vectors shipped with the batch;
  2. submit the step's epoch batch to the Timing Analyzer — by default
     **asynchronously**: a double-buffered submission queue (depth 2) feeds
     a single worker thread, so the analyzer's device work overlaps the
     next step's native execution (the paper's low-overhead attach model);
  3. dispatch the real step and measure native wall time (the paper's
     "execution of the attached program");
  4. optionally ``time.sleep`` the computed delay — the paper's delay
     injection, making the host observe simulated-topology speed (this
     forces synchronous analysis: the delay must exist before it can be
     injected).

All epochs of a step go through :meth:`EpochAnalyzer.analyze_batch` as one
device dispatch; results cross the host boundary once per step, not once
per epoch.  Reading :attr:`AttachedProgram.report` flushes any in-flight
async work first, so observed totals are always consistent.

Two clocks are reported:

  * ``native_s``    — measured host execution time,
  * ``simulated_s`` — native + Σ delays (what the topology would impose),

plus the per-component delay decomposition, per-pool/switch, per-epoch.
``analyzer_s`` stays the analyzer's own compute seconds (the paper's
overhead accounting) whether or not it overlapped native execution.

This module attaches **one** program to a private topology.  To co-attach
several programs on one shared fabric — cross-host contention at shared
switches, trace-driven coherency — use
:class:`repro.core.fabric.FabricSession`, which composes the same tracer /
timer / analyzer stack over a merged multi-host timeline.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .analyzer import DelayBreakdown, EpochAnalyzer, FineGrainedSimulator
from .cache import DeviceCacheConfig, DeviceCacheModel
from .coherency import CoherencyModel
from .events import MemEvents, RegionMap
from .migration import MigrationSimulator
from .policy import PlacementPolicy, capacity_check
from .timer import EpochSchedule
from .topology import Topology
from .tracer import HardwareModel, Phase, TPU_V5E, synthesize_step_trace

__all__ = ["CXLMemSim", "AttachedProgram", "SimReport"]


@dataclasses.dataclass
class SimReport:
    steps: int = 0
    epochs: int = 0
    native_s: float = 0.0
    simulated_s: float = 0.0
    latency_s: float = 0.0
    congestion_s: float = 0.0
    bandwidth_s: float = 0.0
    coherency_s: float = 0.0
    injected_sleep_s: float = 0.0
    analyzer_s: float = 0.0  # simulator's own cost (overhead accounting)
    per_pool_latency_ns: Optional[np.ndarray] = None
    per_switch_congestion_ns: Optional[np.ndarray] = None
    per_switch_bandwidth_ns: Optional[np.ndarray] = None
    migration_moved_bytes: float = 0.0
    cache_hit_fraction: float = float("nan")  # device-cache running hit rate

    @property
    def slowdown(self) -> float:
        """Simulated time / native time — the paper's headline metric."""
        return self.simulated_s / self.native_s if self.native_s > 0 else float("nan")

    @property
    def overhead(self) -> float:
        """(native + analyzer + injected) / native: host-side cost of simulating."""
        if self.native_s <= 0:
            return float("nan")
        return (self.native_s + self.analyzer_s + self.injected_sleep_s) / self.native_s

    def summary(self) -> Dict[str, float]:
        return {
            "steps": self.steps,
            "epochs": self.epochs,
            "native_s": self.native_s,
            "simulated_s": self.simulated_s,
            "slowdown": self.slowdown,
            "latency_s": self.latency_s,
            "congestion_s": self.congestion_s,
            "bandwidth_s": self.bandwidth_s,
            "coherency_s": self.coherency_s,
            "analyzer_s": self.analyzer_s,
        }


class CXLMemSim:
    """Configure once, attach to any number of step functions."""

    def __init__(
        self,
        topology: Topology,
        policy: PlacementPolicy,
        epoch: EpochSchedule = EpochSchedule("step"),
        hw: HardwareModel = TPU_V5E,
        inject_delays: bool = False,
        sample_rate: float = 1.0,
        migration: Optional[MigrationSimulator] = None,
        cache: Optional[DeviceCacheConfig] = None,
        coherency: Optional[CoherencyModel] = None,
        analyzer: str = "epoch",  # 'epoch' (paper) | 'fine' (Gem5-like baseline)
        n_windows: int = 128,
        check_capacity: bool = True,
        max_events_per_access: int = 64,  # trace fidelity (higher = finer)
        async_analysis: Optional[bool] = None,  # None: auto (see below)
    ):
        self.topology = topology
        self.flat = topology.flatten()
        self.policy = policy
        self.epoch = epoch
        self.hw = hw
        self.inject_delays = inject_delays
        self.sample_rate = sample_rate
        self.migration = migration
        self.cache = cache
        self.coherency = coherency
        self.analyzer_kind = analyzer
        self.n_windows = n_windows
        self.check_capacity = check_capacity
        self.max_events_per_access = max_events_per_access
        # async analysis overlaps analyzer work with native execution; delay
        # injection needs the delay before the step returns, so it forces
        # the synchronous path
        if async_analysis is None:
            async_analysis = analyzer == "epoch" and not inject_delays
        self.async_analysis = bool(async_analysis) and not inject_delays

    def attach(
        self,
        step_fn: Callable[..., Any],
        phases: Sequence[Phase],
        regions: RegionMap,
        calibration: float = 1.0,
    ) -> "AttachedProgram":
        self.policy.place(regions, self.flat)
        if self.check_capacity:
            capacity_check(regions, self.flat)
        return AttachedProgram(self, step_fn, list(phases), regions, calibration)


class _AnalysisPipeline:
    """Double-buffered async analysis: a depth-2 submission queue feeds one
    worker thread.  ``submit`` blocks only when two step batches are already
    in flight (backpressure), so analyzer device work overlaps the attached
    program's native execution.  ``flush`` drains the queue and re-raises
    the first worker exception (later batches are still analyzed — they are
    independent — so only the failing batch's epochs are missing from the
    report, and the raised error announces it).

    The worker holds only a weak reference to its :class:`AttachedProgram`
    and polls with a timeout, so abandoning a program (without calling
    ``close``) lets both be garbage-collected instead of leaking one parked
    thread per ``attach``."""

    _POLL_S = 10.0

    def __init__(self, prog: "AttachedProgram"):
        import weakref

        self._prog = weakref.ref(prog)
        self._q: "queue.Queue[Optional[Tuple[List[MemEvents], float, Optional[List]]]]" = (
            queue.Queue(maxsize=2)
        )
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._worker, name="cxlmemsim-analyzer", daemon=True
        )
        self._thread.start()

    def _worker(self):
        while True:
            try:
                item = self._q.get(timeout=self._POLL_S)
            except queue.Empty:
                if self._prog() is None:  # owner was garbage-collected
                    return
                continue
            if item is None:
                self._q.task_done()
                return
            try:
                prog = self._prog()
                if prog is not None:
                    prog._analyze_and_accumulate(*item)
            except BaseException as e:  # first error wins; surfaced on flush()
                if self._error is None:
                    self._error = e
            finally:
                # drop frame locals before blocking on the next get():
                # a lingering strong ref here would defeat the weakref
                prog = item = None
                self._q.task_done()

    def submit(
        self, traces: List[MemEvents], coh_ns: float, scales: Optional[List] = None
    ) -> None:
        if not self._thread.is_alive():
            raise RuntimeError(
                "analysis pipeline is closed — step() after close() would "
                "enqueue work no worker will ever drain"
            )
        self._q.put((traces, coh_ns, scales))

    def flush(self) -> None:
        self._q.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def close(self) -> None:
        if self._thread.is_alive():
            self._q.put(None)
            self._thread.join()


class AttachedProgram:
    def __init__(
        self,
        sim: CXLMemSim,
        step_fn: Callable[..., Any],
        phases: List[Phase],
        regions: RegionMap,
        calibration: float,
    ):
        self.sim = sim
        self.step_fn = step_fn
        self.phases = phases
        self.regions = regions
        self.calibration = calibration
        if sim.analyzer_kind == "epoch":
            self._analyzer = EpochAnalyzer(sim.flat, n_windows=sim.n_windows)
        else:
            self._analyzer = FineGrainedSimulator(sim.flat, bandwidth_mode="per_txn")
        self._cache = (
            DeviceCacheModel(sim.cache, sim.flat, [regions])
            if sim.cache is not None
            else None
        )
        self._report = SimReport(
            per_pool_latency_ns=np.zeros((sim.flat.n_pools,)),
            per_switch_congestion_ns=np.zeros((sim.flat.n_switches,)),
            per_switch_bandwidth_ns=np.zeros((sim.flat.n_switches,)),
        )
        self._report_lock = threading.Lock()
        self._trace_cache: Optional[tuple] = None
        self._pipeline = _AnalysisPipeline(self) if sim.async_analysis else None

    # ------------------------------------------------------------------ #

    @property
    def report(self) -> SimReport:
        """The accumulated report; flushes in-flight async analysis first."""
        self.flush()
        return self._report

    def flush(self) -> None:
        """Block until every submitted epoch batch has been analyzed."""
        if self._pipeline is not None:
            self._pipeline.flush()

    def close(self) -> None:
        """Flush and stop the async analysis worker (idempotent)."""
        if self._pipeline is not None:
            self._pipeline.flush()
            self._pipeline.close()

    # ------------------------------------------------------------------ #

    def _traces(self):
        """Structural traces are shape-static per step; cache across steps,
        but recompute when migration has changed residency."""
        if self._trace_cache is None or self.sim.migration is not None:
            mode = "layer" if self.sim.epoch.mode == "layer" else "step"
            traces, native_ns, names = synthesize_step_trace(
                self.phases,
                self.regions,
                hw=self.sim.hw,
                granularity_bytes=self.sim.policy.granularity_bytes,
                max_events_per_access=self.sim.max_events_per_access,
                calibration=self.calibration,
                epoch_mode=mode,
            )
            if self.sim.epoch.mode == "quantum":
                cut: List[MemEvents] = []
                for tr in traces:
                    cut.extend(self.sim.epoch.slices(tr))
                traces = cut
                native_ns = [self.sim.epoch.quantum_ns] * len(traces)
                names = [f"q{i}" for i in range(len(traces))]
            if self.sim.sample_rate < 1.0:
                traces = [t.sample(self.sim.sample_rate, seed=i) for i, t in enumerate(traces)]
            self._trace_cache = (traces, native_ns, names)
        return self._trace_cache

    def _epoch_batch(self) -> Tuple[List[MemEvents], float, Optional[List]]:
        """One step's epoch traces with migration/coherency/cache applied.

        Stateful transforms run on the submitting thread so their epoch
        order is deterministic; only the (pure) analysis is offloaded.
        The device cache observes the *final* per-epoch stream (including
        injected migration and BI traffic, which warms and pollutes it like
        any other access) and returns per-epoch latency-scale vectors."""
        traces, _, _ = self._traces()
        from .events import concat_events  # local import to avoid cycle

        batch: List[MemEvents] = []
        scales: Optional[List] = [] if self._cache is not None else None
        coh_ns_total = 0.0
        for tr in traces:
            if self.sim.migration is not None:
                tr, extra = self.sim.migration.observe_and_migrate(tr)
                if extra.n:
                    tr = concat_events([tr, extra])
                self._report.migration_moved_bytes = self.sim.migration.moved_bytes_total
            if self.sim.coherency is not None:
                bi, coh_ns = self.sim.coherency.epoch_traffic(tr)
                coh_ns_total += coh_ns
                if bi.n:
                    tr = concat_events([tr, bi])
            if self._cache is not None:
                scales.append(self._cache.observe_scale(tr))
                self._report.cache_hit_fraction = self._cache.hit_fraction
            batch.append(tr)
        return batch, coh_ns_total, scales

    def _analyze_and_accumulate(
        self, batch: List[MemEvents], coh_ns: float, scales: Optional[List] = None
    ) -> float:
        """Analyze one step's epoch batch and fold it into the report.

        Runs on the async worker thread (or inline in sync mode); returns
        the step's total delay in ns.  ``analyzer_s`` accumulates the
        analyzer's own compute time regardless of overlap."""
        a0 = time.perf_counter()
        if isinstance(self._analyzer, EpochAnalyzer):
            bd: DelayBreakdown = self._analyzer.analyze_batch(batch, scales)
        else:
            bd = DelayBreakdown.zero(self.sim.flat.n_pools, self.sim.flat.n_switches)
            for i, tr in enumerate(batch):
                bd = bd + self._analyzer.simulate(
                    tr, None if scales is None else scales[i]
                )
        elapsed = time.perf_counter() - a0
        delay_ns = bd.total_ns + coh_ns
        with self._report_lock:
            r = self._report
            r.epochs += len(batch)
            r.latency_s += bd.latency_ns * 1e-9
            r.congestion_s += bd.congestion_ns * 1e-9
            r.bandwidth_s += bd.bandwidth_ns * 1e-9
            r.coherency_s += coh_ns * 1e-9
            r.per_pool_latency_ns += bd.per_pool_latency_ns
            r.per_switch_congestion_ns += bd.per_switch_congestion_ns
            r.per_switch_bandwidth_ns += bd.per_switch_bandwidth_ns
            r.simulated_s += delay_ns * 1e-9
            r.analyzer_s += elapsed
        return delay_ns

    def step(self, *args, **kwargs):
        """Run one real step under simulation; returns the step's outputs.

        In async mode the step's epoch batch is submitted *before* the
        native dispatch, so the analyzer works while the step executes;
        totals become visible via :attr:`report` (which flushes)."""
        batch, coh_ns, scales = self._epoch_batch()
        if self._pipeline is not None:
            self._pipeline.submit(batch, coh_ns, scales)

        t0 = time.perf_counter()
        out = self.step_fn(*args, **kwargs)
        jax.block_until_ready(out)
        native = time.perf_counter() - t0
        with self._report_lock:
            self._report.native_s += native
            self._report.simulated_s += native
            self._report.steps += 1

        if self._pipeline is None:
            delay_ns = self._analyze_and_accumulate(batch, coh_ns, scales)
            if self.sim.inject_delays and delay_ns > 0:
                # the paper's delay injection: the host program observes the
                # simulated-topology execution speed
                time.sleep(delay_ns * 1e-9)
                self._report.injected_sleep_s += delay_ns * 1e-9
        return out

    def run(self, n_steps: int, *args, **kwargs) -> SimReport:
        for _ in range(n_steps):
            self.step(*args, **kwargs)
        self.flush()
        return self._report
