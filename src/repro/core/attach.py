"""CXLMemSim.attach — the user-facing simulator (paper Figure 2, assembled).

Wraps any jitted step function.  Per step:

  1. dispatch the real step and measure native wall time (the paper's
     "execution of the attached program");
  2. cut the step's structural trace into epochs (Timer);
  3. per epoch: apply migration remapping, inject coherency traffic, run the
     Timing Analyzer, accumulate the three delays;
  4. optionally ``time.sleep`` the computed delay — the paper's delay
     injection, making the host observe simulated-topology speed.

Two clocks are reported:

  * ``native_s``    — measured host execution time,
  * ``simulated_s`` — native + Σ delays (what the topology would impose),

plus the per-component delay decomposition, per-pool/switch, per-epoch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from .analyzer import DelayBreakdown, EpochAnalyzer, FineGrainedSimulator
from .coherency import CoherencyModel
from .events import MemEvents, RegionMap
from .migration import MigrationSimulator
from .policy import PlacementPolicy, capacity_check
from .timer import EpochSchedule
from .topology import Topology
from .tracer import HardwareModel, Phase, TPU_V5E, synthesize_step_trace

__all__ = ["CXLMemSim", "AttachedProgram", "SimReport"]


@dataclasses.dataclass
class SimReport:
    steps: int = 0
    epochs: int = 0
    native_s: float = 0.0
    simulated_s: float = 0.0
    latency_s: float = 0.0
    congestion_s: float = 0.0
    bandwidth_s: float = 0.0
    coherency_s: float = 0.0
    injected_sleep_s: float = 0.0
    analyzer_s: float = 0.0  # simulator's own cost (overhead accounting)
    per_pool_latency_ns: Optional[np.ndarray] = None
    per_switch_congestion_ns: Optional[np.ndarray] = None
    per_switch_bandwidth_ns: Optional[np.ndarray] = None
    migration_moved_bytes: float = 0.0

    @property
    def slowdown(self) -> float:
        """Simulated time / native time — the paper's headline metric."""
        return self.simulated_s / self.native_s if self.native_s > 0 else float("nan")

    @property
    def overhead(self) -> float:
        """(native + analyzer + injected) / native: host-side cost of simulating."""
        if self.native_s <= 0:
            return float("nan")
        return (self.native_s + self.analyzer_s + self.injected_sleep_s) / self.native_s

    def summary(self) -> Dict[str, float]:
        return {
            "steps": self.steps,
            "epochs": self.epochs,
            "native_s": self.native_s,
            "simulated_s": self.simulated_s,
            "slowdown": self.slowdown,
            "latency_s": self.latency_s,
            "congestion_s": self.congestion_s,
            "bandwidth_s": self.bandwidth_s,
            "coherency_s": self.coherency_s,
            "analyzer_s": self.analyzer_s,
        }


class CXLMemSim:
    """Configure once, attach to any number of step functions."""

    def __init__(
        self,
        topology: Topology,
        policy: PlacementPolicy,
        epoch: EpochSchedule = EpochSchedule("step"),
        hw: HardwareModel = TPU_V5E,
        inject_delays: bool = False,
        sample_rate: float = 1.0,
        migration: Optional[MigrationSimulator] = None,
        coherency: Optional[CoherencyModel] = None,
        analyzer: str = "epoch",  # 'epoch' (paper) | 'fine' (Gem5-like baseline)
        n_windows: int = 128,
        check_capacity: bool = True,
        max_events_per_access: int = 64,  # trace fidelity (higher = finer)
    ):
        self.topology = topology
        self.flat = topology.flatten()
        self.policy = policy
        self.epoch = epoch
        self.hw = hw
        self.inject_delays = inject_delays
        self.sample_rate = sample_rate
        self.migration = migration
        self.coherency = coherency
        self.analyzer_kind = analyzer
        self.n_windows = n_windows
        self.check_capacity = check_capacity
        self.max_events_per_access = max_events_per_access

    def attach(
        self,
        step_fn: Callable[..., Any],
        phases: Sequence[Phase],
        regions: RegionMap,
        calibration: float = 1.0,
    ) -> "AttachedProgram":
        self.policy.place(regions, self.flat)
        if self.check_capacity:
            capacity_check(regions, self.flat)
        return AttachedProgram(self, step_fn, list(phases), regions, calibration)


class AttachedProgram:
    def __init__(
        self,
        sim: CXLMemSim,
        step_fn: Callable[..., Any],
        phases: List[Phase],
        regions: RegionMap,
        calibration: float,
    ):
        self.sim = sim
        self.step_fn = step_fn
        self.phases = phases
        self.regions = regions
        self.calibration = calibration
        if sim.analyzer_kind == "epoch":
            self._analyzer = EpochAnalyzer(sim.flat, n_windows=sim.n_windows)
            self._analyze = self._analyzer.analyze
        else:
            self._analyzer = FineGrainedSimulator(sim.flat, bandwidth_mode="per_txn")
            self._analyze = self._analyzer.simulate
        self.report = SimReport(
            per_pool_latency_ns=np.zeros((sim.flat.n_pools,)),
            per_switch_congestion_ns=np.zeros((sim.flat.n_switches,)),
            per_switch_bandwidth_ns=np.zeros((sim.flat.n_switches,)),
        )
        self._trace_cache: Optional[tuple] = None

    # ------------------------------------------------------------------ #

    def _traces(self):
        """Structural traces are shape-static per step; cache across steps,
        but recompute when migration has changed residency."""
        if self._trace_cache is None or self.sim.migration is not None:
            mode = "layer" if self.sim.epoch.mode == "layer" else "step"
            traces, native_ns, names = synthesize_step_trace(
                self.phases,
                self.regions,
                hw=self.sim.hw,
                granularity_bytes=self.sim.policy.granularity_bytes,
                max_events_per_access=self.sim.max_events_per_access,
                calibration=self.calibration,
                epoch_mode=mode,
            )
            if self.sim.epoch.mode == "quantum":
                cut: List[MemEvents] = []
                for tr in traces:
                    cut.extend(self.sim.epoch.slices(tr))
                traces = cut
                native_ns = [self.sim.epoch.quantum_ns] * len(traces)
                names = [f"q{i}" for i in range(len(traces))]
            if self.sim.sample_rate < 1.0:
                traces = [t.sample(self.sim.sample_rate, seed=i) for i, t in enumerate(traces)]
            self._trace_cache = (traces, native_ns, names)
        return self._trace_cache

    def step(self, *args, **kwargs):
        """Run one real step under simulation; returns the step's outputs."""
        t0 = time.perf_counter()
        out = self.step_fn(*args, **kwargs)
        jax.block_until_ready(out)
        native = time.perf_counter() - t0
        self.report.native_s += native
        self.report.steps += 1

        a0 = time.perf_counter()
        delay_ns = 0.0
        traces, _, _ = self._traces()
        from .events import concat_events  # local import to avoid cycle

        for tr in traces:
            if self.sim.migration is not None:
                tr, extra = self.sim.migration.observe_and_migrate(tr)
                if extra.n:
                    tr = concat_events([tr, extra])
                self.report.migration_moved_bytes = self.sim.migration.moved_bytes_total
            coh_ns = 0.0
            if self.sim.coherency is not None:
                bi, coh_ns = self.sim.coherency.epoch_traffic(tr)
                if bi.n:
                    tr = concat_events([tr, bi])
            bd: DelayBreakdown = self._analyze(tr)
            self.report.epochs += 1
            self.report.latency_s += bd.latency_ns * 1e-9
            self.report.congestion_s += bd.congestion_ns * 1e-9
            self.report.bandwidth_s += bd.bandwidth_ns * 1e-9
            self.report.coherency_s += coh_ns * 1e-9
            self.report.per_pool_latency_ns += bd.per_pool_latency_ns
            self.report.per_switch_congestion_ns += bd.per_switch_congestion_ns
            self.report.per_switch_bandwidth_ns += bd.per_switch_bandwidth_ns
            delay_ns += bd.total_ns + coh_ns
        self.report.analyzer_s += time.perf_counter() - a0

        self.report.simulated_s += native + delay_ns * 1e-9
        if self.sim.inject_delays and delay_ns > 0:
            # the paper's delay injection: the host program observes the
            # simulated-topology execution speed
            time.sleep(delay_ns * 1e-9)
            self.report.injected_sleep_s += delay_ns * 1e-9
        return out

    def run(self, n_steps: int, *args, **kwargs) -> SimReport:
        for _ in range(n_steps):
            self.step(*args, **kwargs)
        return self.report
