"""Epoch segmentation — the paper's Timer (§3, component 2).

The paper interrupts the traced program periodically; each interval is an
epoch and the Timing Analyzer runs at the boundary.  In the JAX setting the
natural epoch boundaries are dispatch points:

  * ``'step'``   — one jitted train/serve step per epoch (default),
  * ``'layer'``  — one transformer layer per epoch (finer attribution; the
                   tracer emits per-layer event slices),
  * ``'quantum'``— fixed simulated-time quantum: a step's trace is re-cut
                   into fixed-duration slices, mimicking the paper's
                   wall-clock epoch timer.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

from .events import MemEvents

__all__ = ["EpochSchedule", "slice_by_quantum"]


@dataclasses.dataclass(frozen=True)
class EpochSchedule:
    """How execution is divided into epochs."""

    mode: str = "step"  # 'step' | 'layer' | 'quantum'
    quantum_ns: float = 1e6  # used when mode == 'quantum'

    def __post_init__(self):
        if self.mode not in ("step", "layer", "quantum"):
            raise ValueError(f"unknown epoch mode {self.mode!r}")
        if self.quantum_ns <= 0:
            raise ValueError("quantum_ns must be positive")

    def slices(self, trace: MemEvents) -> List[MemEvents]:
        """Cut one step's trace into epoch slices (times re-based per slice)."""
        if self.mode in ("step", "layer"):
            # 'layer' slicing is done upstream by the tracer (it knows layer
            # boundaries); at this point each trace is already one epoch.
            return [trace]
        return slice_by_quantum(trace, self.quantum_ns)


def slice_by_quantum(trace: MemEvents, quantum_ns: float) -> List[MemEvents]:
    if trace.n == 0:
        return []
    ev = trace.sorted_by_time()
    out: List[MemEvents] = []
    k = np.floor(ev.t_ns / quantum_ns).astype(np.int64)
    for q in np.unique(k):
        idx = np.nonzero(k == q)[0]
        sl = ev.take(idx)
        out.append(
            MemEvents(
                t_ns=sl.t_ns - q * quantum_ns,  # re-base to epoch start
                pool=sl.pool,
                bytes_=sl.bytes_,
                is_write=sl.is_write,
                region=sl.region,
            )
        )
    return out
