"""Epoch segmentation — the paper's Timer (§3, component 2).

The paper interrupts the traced program periodically; each interval is an
epoch and the Timing Analyzer runs at the boundary.  In the JAX setting the
natural epoch boundaries are dispatch points:

  * ``'step'``   — one jitted train/serve step per epoch (default),
  * ``'layer'``  — one transformer layer per epoch (finer attribution; the
                   tracer emits per-layer event slices),
  * ``'quantum'``— fixed simulated-time quantum: a step's trace is re-cut
                   into fixed-duration slices, mimicking the paper's
                   wall-clock epoch timer.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .events import MemEvents
from .units import NS_PER_MS

__all__ = ["EpochSchedule", "slice_by_quantum"]


@dataclasses.dataclass(frozen=True)
class EpochSchedule:
    """How execution is divided into epochs."""

    mode: str = "step"  # 'step' | 'layer' | 'quantum'
    quantum_ns: float = float(NS_PER_MS)  # 1 ms; used when mode == 'quantum'

    def __post_init__(self):
        if self.mode not in ("step", "layer", "quantum"):
            raise ValueError(f"unknown epoch mode {self.mode!r}")
        if self.quantum_ns <= 0:
            raise ValueError("quantum_ns must be positive")

    def slices(self, trace: MemEvents, dense: bool = False) -> List[MemEvents]:
        """Cut one step's trace into epoch slices (times re-based per slice)."""
        if self.mode in ("step", "layer"):
            # 'layer' slicing is done upstream by the tracer (it knows layer
            # boundaries); at this point each trace is already one epoch.
            return [trace]
        return slice_by_quantum(trace, self.quantum_ns, dense=dense)


def slice_by_quantum(
    trace: MemEvents, quantum_ns: float, dense: bool = False
) -> List[MemEvents]:
    """Cut a trace on fixed simulated-time quanta.

    By default idle quanta are dropped (the single-host attach behavior:
    only occupied epochs are analyzed).  With ``dense=True`` the returned
    list covers every quantum from 0 through the last occupied one, empty
    slices included, so index ``k`` always means *absolute* quantum ``k`` —
    required when several hosts' slice streams are aligned positionally
    (the fabric session's co-scheduling contract).
    """
    if trace.n == 0:
        return []
    ev = trace.sorted_by_time()
    out: List[MemEvents] = []
    k = np.floor(ev.t_ns / quantum_ns).astype(np.int64)
    if dense:
        # k is non-decreasing (ev is time-sorted): all slice boundaries in
        # one O(N + Q) searchsorted instead of one array scan per quantum
        qmax = int(k[-1])
        bounds = np.searchsorted(k, np.arange(qmax + 2))
        groups = [
            (q, np.arange(bounds[q], bounds[q + 1])) for q in range(qmax + 1)
        ]
    else:
        groups = [(int(q), np.nonzero(k == q)[0]) for q in np.unique(k)]
    for q, idx in groups:
        sl = ev.take(idx)
        # re-base times to the slice's epoch start; every other field —
        # including PEBS-style sampling weights and host tags — rides along
        out.append(dataclasses.replace(sl, t_ns=sl.t_ns - q * quantum_ns))
    return out
