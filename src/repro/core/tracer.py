"""The Tracer (paper §3, component 1), adapted to JAX.

The paper traces (a) allocations via eBPF and (b) memory events via PEBS.
Neither exists on TPU, so the tracer is re-thought around what the JAX stack
gives us exactly:

  * **structural trace** — models describe each step as a list of
    :class:`Phase` objects (one per layer/sub-block) with logical
    :class:`Access` records (which region, how many bytes, read or write).
    This is the pool-attribution source, playing the role of the eBPF
    address-range map.
  * **HLO calibration** — ``compiled.cost_analysis()`` gives the exact FLOPs
    and bytes the compiled step moves; the structural trace is scaled so its
    totals match the compiled artifact (fusion changes totals; calibration
    absorbs that).
  * **collective extraction** — collective bytes are parsed from the
    compiled HLO text (see :mod:`repro.core.roofline`) and can be modelled as
    traffic through "ICI switch" components of a topology.

Event batching: a logical access of B bytes at granule g becomes
``min(ceil(B/g), max_events)`` events carrying equal byte shares.  Aggregate
bytes are exact; only the event count is coalesced, which is the same fidelity
trade PEBS sampling makes (documented in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .events import MemEvents, RegionMap, concat_events

__all__ = [
    "Access",
    "Phase",
    "HardwareModel",
    "TPU_V5E",
    "synthesize_step_trace",
    "phase_duration_ns",
    "hlo_cost_summary",
]


@dataclasses.dataclass(frozen=True)
class Access:
    """One logical tensor access inside a phase."""

    region: str
    bytes_: float
    is_write: bool = False


@dataclasses.dataclass(frozen=True)
class Phase:
    """One schedulable unit of a step (a layer, a collective, an update)."""

    name: str
    flops: float
    accesses: Tuple[Access, ...]

    def total_bytes(self) -> float:
        return sum(a.bytes_ for a in self.accesses)


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Roofline constants used to pace issue times (and by §Roofline)."""

    name: str
    peak_flops: float  # FLOP/s (bf16 for TPU)
    hbm_gbps: float  # bytes/ns == GB/s
    ici_gbps: float  # per-link ICI bandwidth

    def phase_ns(self, flops: float, bytes_: float) -> float:
        """Roofline-paced duration: max of compute time and memory time."""
        t_c = flops / self.peak_flops * 1e9
        t_m = bytes_ / self.hbm_gbps  # GB/s == bytes/ns
        return max(t_c, t_m, 1.0)


TPU_V5E = HardwareModel(
    name="tpu_v5e", peak_flops=197e12, hbm_gbps=819.0, ici_gbps=50.0
)


def phase_duration_ns(phase: Phase, hw: HardwareModel) -> float:
    return hw.phase_ns(phase.flops, phase.total_bytes())


def synthesize_step_trace(
    phases: Sequence[Phase],
    regions: RegionMap,
    hw: HardwareModel = TPU_V5E,
    granularity_bytes: float = 64.0,
    max_events_per_access: int = 64,
    calibration: float = 1.0,
    epoch_mode: str = "step",
) -> Tuple[List[MemEvents], List[float], List[str]]:
    """Expand a phase list into per-epoch event traces.

    Returns ``(traces, native_ns, epoch_names)``; in ``'step'`` mode there is
    one epoch covering all phases, in ``'layer'`` mode one epoch per phase.
    ``calibration`` scales every byte count (from HLO calibration).
    """
    if epoch_mode not in ("step", "layer"):
        raise ValueError(epoch_mode)
    per_phase: List[MemEvents] = []
    durations: List[float] = []
    t_cursor = 0.0
    for ph in phases:
        dur = phase_duration_ns(ph, hw)
        parts: List[MemEvents] = []
        for a in ph.accesses:
            if a.region not in regions:
                raise KeyError(f"phase {ph.name}: unknown region {a.region!r}")
            r = regions[a.region]
            b = a.bytes_ * calibration
            n_ev = int(min(max(np.ceil(b / granularity_bytes), 1), max_events_per_access))
            share = b / n_ev
            # deterministic uniform spread across the phase (no RNG: traces
            # must be reproducible for regression tests)
            offs = (np.arange(n_ev, dtype=np.float64) + 0.5) / n_ev * dur
            base = 0.0 if epoch_mode == "layer" else t_cursor
            parts.append(
                MemEvents(
                    t_ns=base + offs,
                    pool=np.full((n_ev,), r.pool, np.int32),
                    bytes_=np.full((n_ev,), share, np.float64),
                    is_write=np.full((n_ev,), a.is_write, bool),
                    region=np.full((n_ev,), r.rid, np.int32),
                )
            )
        per_phase.append(concat_events(parts))
        durations.append(dur)
        t_cursor += dur

    if epoch_mode == "layer":
        return per_phase, durations, [ph.name for ph in phases]
    return (
        [concat_events(per_phase)],
        [float(sum(durations))],
        ["step"],
    )


# --------------------------------------------------------------------------- #
# HLO calibration helpers
# --------------------------------------------------------------------------- #


def hlo_cost_summary(compiled) -> Dict[str, float]:
    """Extract FLOPs / bytes-accessed from a compiled step."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return {"flops": flops, "bytes_accessed": bytes_accessed}


def calibration_factor(structural_bytes: float, compiled_bytes: float) -> float:
    """Scale factor applied to structural traces so totals match the HLO."""
    if structural_bytes <= 0:
        return 1.0
    return compiled_bytes / structural_bytes
