"""The Tracer (paper §3, component 1), adapted to JAX.

The paper traces (a) allocations via eBPF and (b) memory events via PEBS.
Neither exists on TPU, so the tracer is re-thought around what the JAX stack
gives us exactly:

  * **structural trace** — models describe each step as a list of
    :class:`Phase` objects (one per layer/sub-block) with logical
    :class:`Access` records (which region, how many bytes, read or write).
    This is the pool-attribution source, playing the role of the eBPF
    address-range map.
  * **HLO calibration** — ``compiled.cost_analysis()`` gives the exact FLOPs
    and bytes the compiled step moves; the structural trace is scaled so its
    totals match the compiled artifact (fusion changes totals; calibration
    absorbs that).
  * **collective extraction** — collective bytes are parsed from the
    compiled HLO text (see :mod:`repro.core.roofline`) and can be modelled as
    traffic through "ICI switch" components of a topology.

Event batching: a logical access of B bytes at granule g becomes
``min(ceil(B/g), max_events)`` events carrying equal byte shares.  Aggregate
bytes are exact; only the event count is coalesced, which is the same fidelity
trade PEBS sampling makes (documented in DESIGN.md).

Synthesis is split into two halves so scenario sweeps don't re-pay it:

  * :func:`synthesize_skeleton` builds the **placement-independent**
    structural skeleton — event times, byte shares, region ids, epoch
    boundaries — once, with array ops (``np.repeat`` expansion; no
    per-access Python loop over events).  Everything in it depends only on
    the phase list, the hardware model, and the granule.
  * :func:`skeleton_to_events` is the cheap per-scenario step: one gather
    of a ``[R]`` region→pool vector through the skeleton's region ids.  K
    scenarios that share a granularity share one skeleton.

:func:`synthesize_step_trace` composes the two for the historical
single-placement API (bit-identical output, same event order).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .events import MemEvents, RegionMap
from .units import s_to_ns

__all__ = [
    "Access",
    "Phase",
    "HardwareModel",
    "TPU_V5E",
    "TraceSkeleton",
    "skeleton_to_events",
    "synthesize_skeleton",
    "synthesize_step_trace",
    "phase_duration_ns",
    "hlo_cost_summary",
]


@dataclasses.dataclass(frozen=True)
class Access:
    """One logical tensor access inside a phase."""

    region: str
    bytes_: float
    is_write: bool = False


@dataclasses.dataclass(frozen=True)
class Phase:
    """One schedulable unit of a step (a layer, a collective, an update)."""

    name: str
    flops: float
    accesses: Tuple[Access, ...]

    def total_bytes(self) -> float:
        return sum(a.bytes_ for a in self.accesses)


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Roofline constants used to pace issue times (and by §Roofline)."""

    name: str
    peak_flops: float  # FLOP/s (bf16 for TPU)
    hbm_gbps: float  # bytes/ns == GB/s
    ici_gbps: float  # per-link ICI bandwidth

    def phase_ns(self, flops: float, bytes_: float) -> float:
        """Roofline-paced duration: max of compute time and memory time."""
        t_c = s_to_ns(flops / self.peak_flops)
        t_m = bytes_ / self.hbm_gbps  # GB/s == bytes/ns
        return max(t_c, t_m, 1.0)


TPU_V5E = HardwareModel(
    name="tpu_v5e", peak_flops=197e12, hbm_gbps=819.0, ici_gbps=50.0
)


def phase_duration_ns(phase: Phase, hw: HardwareModel) -> float:
    return hw.phase_ns(phase.flops, phase.total_bytes())


@dataclasses.dataclass(frozen=True)
class TraceSkeleton:
    """Placement-independent half of a synthesized trace.

    Everything here is fixed once phases, hardware model, granule,
    calibration and epoch mode are fixed — only the per-event *pool*
    changes across placement scenarios, and that is a single gather of a
    region→pool vector through ``region`` (:func:`skeleton_to_events`).

    ``epoch_ptr[e]:epoch_ptr[e+1]`` delimits epoch ``e``'s events (one
    epoch in ``'step'`` mode, one per phase in ``'layer'`` mode); times are
    epoch-relative, exactly as the historical synthesis emitted them.
    """

    t_ns: np.ndarray  # [N] float64 epoch-relative issue times
    bytes_: np.ndarray  # [N] float64 byte share per event
    is_write: np.ndarray  # [N] bool
    region: np.ndarray  # [N] int32 region id
    epoch_ptr: np.ndarray  # [E+1] int64 event-index boundaries per epoch
    native_ns: Tuple[float, ...]  # [E] roofline-paced epoch durations
    epoch_names: Tuple[str, ...]  # [E]
    granularity_bytes: float

    @property
    def n(self) -> int:
        return int(len(self.t_ns))

    @property
    def n_epochs(self) -> int:
        return int(len(self.epoch_ptr) - 1)


def synthesize_skeleton(
    phases: Sequence[Phase],
    regions: RegionMap,
    hw: HardwareModel = TPU_V5E,
    granularity_bytes: float = 64.0,
    max_events_per_access: int = 64,
    calibration: float = 1.0,
    epoch_mode: str = "step",
) -> TraceSkeleton:
    """Build the structural skeleton with array ops (no per-event loop).

    The only Python iteration is over the phase/access *structure* (tens of
    entries); the expansion of each access into its event train — the part
    that scales with trace size — is one ``np.repeat`` + arange pass.
    """
    if epoch_mode not in ("step", "layer"):
        raise ValueError(epoch_mode)
    # structural pass: one row per logical access
    rid: List[int] = []
    acc_bytes: List[float] = []
    acc_write: List[bool] = []
    acc_phase: List[int] = []
    durations: List[float] = []
    counts: List[int] = []  # accesses per phase (for epoch_ptr)
    for pi, ph in enumerate(phases):
        durations.append(phase_duration_ns(ph, hw))
        counts.append(len(ph.accesses))
        for a in ph.accesses:
            if a.region not in regions:
                raise KeyError(f"phase {ph.name}: unknown region {a.region!r}")
            rid.append(regions[a.region].rid)
            acc_bytes.append(a.bytes_ * calibration)
            acc_write.append(a.is_write)
            acc_phase.append(pi)

    dur = np.asarray(durations, np.float64)
    names = tuple(ph.name for ph in phases)
    if not rid:
        empty_ptr = (
            np.zeros((len(phases) + 1,), np.int64)
            if epoch_mode == "layer"
            else np.zeros((2,), np.int64)
        )
        return TraceSkeleton(
            t_ns=np.zeros((0,), np.float64),
            bytes_=np.zeros((0,), np.float64),
            is_write=np.zeros((0,), bool),
            region=np.zeros((0,), np.int32),
            epoch_ptr=empty_ptr,
            native_ns=tuple(dur) if epoch_mode == "layer" else (float(dur.sum()),),
            epoch_names=names if epoch_mode == "layer" else ("step",),
            granularity_bytes=float(granularity_bytes),
        )

    b = np.asarray(acc_bytes, np.float64)
    a_phase = np.asarray(acc_phase, np.int64)
    n_ev = np.minimum(
        np.maximum(np.ceil(b / granularity_bytes), 1), max_events_per_access
    ).astype(np.int64)
    share = b / n_ev  # equal byte shares; aggregate bytes stay exact

    N = int(n_ev.sum())
    excl = np.concatenate([[0], np.cumsum(n_ev)])  # [A+1]
    # per-event index within its access train, via one global arange
    within = np.arange(N, dtype=np.float64) - np.repeat(excl[:-1], n_ev)
    n_ev_rep = np.repeat(n_ev.astype(np.float64), n_ev)
    dur_rep = np.repeat(dur[a_phase], n_ev)
    # deterministic uniform spread across the phase (no RNG: traces must be
    # reproducible for regression tests); same float ops as the historical
    # per-access loop, so outputs are bit-identical
    offs = (within + 0.5) / n_ev_rep * dur_rep
    phase_start = np.concatenate([[0.0], np.cumsum(dur)])[:-1]
    base = 0.0 if epoch_mode == "layer" else np.repeat(phase_start[a_phase], n_ev)
    t = base + offs

    if epoch_mode == "layer":
        # epoch boundaries at phase access-train boundaries
        acc_per_phase = np.concatenate([[0], np.cumsum(counts)])
        epoch_ptr = excl[acc_per_phase]
        native = tuple(float(d) for d in dur)
    else:
        epoch_ptr = np.asarray([0, N], np.int64)
        native = (float(dur.sum()),)
        names = ("step",)
    return TraceSkeleton(
        t_ns=t,
        bytes_=np.repeat(share, n_ev),
        is_write=np.repeat(np.asarray(acc_write, bool), n_ev),
        region=np.repeat(np.asarray(rid, np.int64), n_ev).astype(np.int32),
        epoch_ptr=epoch_ptr,
        native_ns=native,
        epoch_names=names,
        granularity_bytes=float(granularity_bytes),
    )


def skeleton_to_events(
    skeleton: TraceSkeleton, pool_of_region: np.ndarray
) -> List[MemEvents]:
    """The per-scenario half: gather pools, slice epochs.

    ``pool_of_region`` is a ``[n_regions]`` region→pool vector (e.g.
    :meth:`~repro.core.events.RegionMap.pool_vector` or one row of a
    policy ``assign_batch`` matrix).  O(N) gather + views; no synthesis.
    """
    pool = np.asarray(pool_of_region, np.int32)[skeleton.region]
    out: List[MemEvents] = []
    for e in range(skeleton.n_epochs):
        lo, hi = int(skeleton.epoch_ptr[e]), int(skeleton.epoch_ptr[e + 1])
        out.append(
            # skeletons carry no weight/host columns: synthesis is exact
            # (weight 1) and the host tag is applied downstream by with_host
            MemEvents(  # simlint: ignore[event-columns] -- skeleton build: default weight/host are the correct semantics here
                t_ns=skeleton.t_ns[lo:hi],
                pool=pool[lo:hi],
                bytes_=skeleton.bytes_[lo:hi],
                is_write=skeleton.is_write[lo:hi],
                region=skeleton.region[lo:hi],
            )
        )
    return out


def synthesize_step_trace(
    phases: Sequence[Phase],
    regions: RegionMap,
    hw: HardwareModel = TPU_V5E,
    granularity_bytes: float = 64.0,
    max_events_per_access: int = 64,
    calibration: float = 1.0,
    epoch_mode: str = "step",
) -> Tuple[List[MemEvents], List[float], List[str]]:
    """Expand a phase list into per-epoch event traces.

    Returns ``(traces, native_ns, epoch_names)``; in ``'step'`` mode there is
    one epoch covering all phases, in ``'layer'`` mode one epoch per phase.
    ``calibration`` scales every byte count (from HLO calibration).

    Composition of :func:`synthesize_skeleton` (placement-independent) and
    :func:`skeleton_to_events` (pool gather of the regions' current
    placement) — same events, same order as the historical loop.
    """
    skel = synthesize_skeleton(
        phases,
        regions,
        hw,
        granularity_bytes=granularity_bytes,
        max_events_per_access=max_events_per_access,
        calibration=calibration,
        epoch_mode=epoch_mode,
    )
    traces = skeleton_to_events(skel, regions.pool_vector())
    return traces, list(skel.native_ns), list(skel.epoch_names)


# --------------------------------------------------------------------------- #
# HLO calibration helpers
# --------------------------------------------------------------------------- #


def hlo_cost_summary(compiled) -> Dict[str, float]:
    """Extract FLOPs / bytes-accessed from a compiled step."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return {"flops": flops, "bytes_accessed": bytes_accessed}


def calibration_factor(structural_bytes: float, compiled_bytes: float) -> float:
    """Scale factor applied to structural traces so totals match the HLO."""
    if structural_bytes <= 0:
        return 1.0
    return compiled_bytes / structural_bytes
