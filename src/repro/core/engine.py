"""Shared async analysis engine — one dispatcher for every attached session.

The paper's central claim is *low-overhead attach*: the Timing Analyzer must
hide behind the attached program's own execution.  Historically each
``CXLMemSim.attach`` owned a private worker thread (one parked thread per
attach) while ``FabricSession`` analyzed synchronously on the critical path.
:class:`AnalysisEngine` replaces both with one process-wide dispatcher:

  * **Sessions register** (:meth:`AnalysisEngine.register`) and get an
    :class:`EngineHandle`; ``handle.submit(traces, scales, fold=...)``
    enqueues one epoch batch and returns a
    :class:`concurrent.futures.Future` resolving to the batch's
    :class:`~repro.core.analyzer.DelayBreakdown`.
  * **Backpressure**: each handle allows ``max_inflight`` outstanding
    batches (default 2 — the historical double-buffered queue depth);
    ``submit`` blocks past that, so a runaway producer cannot grow the
    queue unboundedly.
  * **Cross-session coalescing**: while the dispatcher is busy, submissions
    from *different* sessions accumulate; same-topology sessions (equal
    :func:`dispatch_key` — route matrix, merge plan, numeric leaves,
    window config) are coalesced into one stacked ``[K, B, N]`` jitted
    dispatch (:meth:`~repro.core.analyzer.EpochAnalyzer.analyze_batch_multi`,
    the cross-session analogue of the scenario suite's ``[K, B, N]``
    stacking) with per-session totals.  Two batches of the *same* session
    are never coalesced — each handle's submissions are processed FIFO,
    one dispatch each, so a solo session's async results stay bit-identical
    to its synchronous path.
  * **Thread-safe folding**: the optional ``fold(breakdown, analyzer_s)``
    callback runs on the dispatcher thread after analysis; sessions fold
    into their reports under their own report lock.
  * **Dropped-batch accounting**: a failing batch is *recorded* —
    ``handle.dropped_batches`` / ``dropped_epochs`` — before the error is
    re-raised (once) from ``handle.flush()``.  Truncated report totals are
    therefore always detectable; see ``SimReport.dropped_epochs``.
  * **Lifecycle**: ``handle.close()`` drains and releases a session;
    ``engine.close()`` (or the engine's context manager) drains everything
    and joins the dispatcher thread.  The lazily-created process-default
    engine (:meth:`AnalysisEngine.default`) keeps one daemon dispatcher
    for the whole process — closing handles never leaks a thread per
    attach the way the old per-program pipeline did.

Staging buffers: the engine owns its :class:`~repro.core.events.EventStager`
set (one per analyzer time-dtype), so host staging never shares mutable
buffers with a session's own synchronous analyzer calls on other threads.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..analysis.annotations import guarded_by, single_threaded
from .analyzer import DelayBreakdown, EpochAnalyzer, PendingBatch, analyze_any
from .events import EventStager, MemEvents

__all__ = [
    "AnalysisEngine",
    "EngineClient",
    "EngineHandle",
    "dispatch_key",
    "fold_dispatch_stats",
]


def dispatch_key(analyzer) -> Optional[Tuple]:
    """Coalescing signature: submissions from handles with equal keys may
    share one stacked dispatch.  ``None`` means "never coalesce" (non-epoch
    analyzers, and the Pallas impls whose ``lax.map`` epoch loop is not
    validated under a session vmap).  The key hashes the topology's numeric
    leaves, not object identity, so distinct sessions on equal topologies
    batch together — the same structural-sharing requirement the scenario
    suite's stacked dispatch imposes."""
    if not isinstance(analyzer, EpochAnalyzer) or analyzer.impl != "inline":
        return None
    flat = analyzer.flat
    return (
        bool(analyzer.pipeline),
        bool(analyzer.fused),
        int(analyzer.n_windows),
        jnp.dtype(analyzer.dtype).name,
        float(analyzer.bw_window_ns),
        analyzer._stage_order,
        analyzer._merge_plan,
        int(flat.n_hosts),
        np.asarray(flat.route).tobytes(),
        np.asarray(flat.pool_latency_ns).tobytes(),
        float(flat.local_latency_ns),
        np.asarray(flat.switch_stt_ns).tobytes(),
        np.asarray(flat.switch_bandwidth_gbps).tobytes(),
    )


def fold_dispatch_stats(report, stats, group_size: int) -> None:
    """Fold one dispatch's sharding observability into a report.

    ``report`` is any object with ``devices_used`` / ``shard_rows`` /
    ``padded_waste`` / ``coalesced_group_size`` fields (SimReport,
    FabricReport).  Device counts, shard widths and group sizes keep their
    maxima (did sharding/coalescing ever engage, and how wide); padded
    waste keeps the worst fraction seen.  The pipeline timing split
    (``stage_s``/``transfer_s``/``compile_s``/``compute_s``) accumulates
    across dispatches, and ``donated_dispatches``/``aot_cache_hits`` count
    how often donation and the AOT cache engaged — coalesced dispatches
    report zero timing on every member handle, so cross-session sharing
    never double-counts.  Callers hold their report lock.
    """
    if stats is not None:
        report.devices_used = max(report.devices_used, stats.devices_used)
        report.shard_rows = max(report.shard_rows, stats.shard_rows)
        report.padded_waste = max(report.padded_waste, stats.padded_fraction)
        report.stage_s += stats.stage_s
        report.transfer_s += stats.transfer_s
        report.compile_s += stats.compile_s
        report.compute_s += stats.compute_s
        if stats.donated:
            report.donated_dispatches += 1
        if stats.aot_cache_hit:
            report.aot_cache_hits += 1
    if group_size:
        report.coalesced_group_size = max(
            report.coalesced_group_size, int(group_size)
        )


@dataclasses.dataclass
class _Submission:
    handle: "EngineHandle"
    traces: List[MemEvents]
    scales: Optional[List]
    fold: Optional[Callable[[DelayBreakdown, float], None]]
    future: Future


@dataclasses.dataclass
class _Launched:
    """One launched-but-unresolved dispatch in the worker's depth-1
    pipeline.  Exactly one of ``pending`` (overlapped solo launch) or
    ``bds`` (synchronously computed results) is set when ``error`` is
    None."""

    group: List[_Submission]
    live: List[_Submission]
    pending: Optional[PendingBatch]
    bds: Optional[List[DelayBreakdown]]
    launch_s: float
    error: Optional[BaseException]


class EngineHandle:
    """One session's port into the engine; created by
    :meth:`AnalysisEngine.register`.  Not constructed directly."""

    # handle state is shared between the submitting thread and the
    # dispatcher; everything mutable rides under the engine's one lock
    _simlint_guards = guarded_by(
        "_cv",
        "_inflight",
        "_error",
        "_closed",
        "dropped_batches",
        "dropped_epochs",
        "_pending",
        "_broken",
    )

    def __init__(
        self,
        engine: "AnalysisEngine",
        analyzer,
        key: Optional[Tuple],
        max_inflight: int,
    ):
        self.engine = engine
        self.analyzer = analyzer
        self.key = key
        if int(max_inflight) < 1:
            raise ValueError(
                f"max_inflight must be >= 1, got {max_inflight} — a 0-depth "
                "handle could never admit a submission"
            )
        self.max_inflight = int(max_inflight)
        self._inflight = 0  # guarded by engine._cv
        self._error: Optional[BaseException] = None
        self._closed = False
        self.dropped_batches = 0
        self.dropped_epochs = 0
        # dispatch observability, written by the dispatcher thread before
        # fold callbacks run (sessions copy these into their reports)
        self.last_dispatch = None  # Optional[DispatchStats]
        self.last_group_size = 0

    # -- session-facing API -------------------------------------------------- #

    def submit(
        self,
        traces: Sequence[MemEvents],
        scales: Optional[Sequence] = None,
        fold: Optional[Callable[[DelayBreakdown, float], None]] = None,
    ) -> Future:
        """Enqueue one epoch batch; returns a Future of its breakdown.

        Blocks while ``max_inflight`` batches of this handle are already in
        flight (backpressure).  ``fold(breakdown, analyzer_s)`` runs on the
        dispatcher thread after analysis, before the future resolves;
        ``analyzer_s`` is this batch's share of the dispatch's compute
        seconds (attributed by epoch count when coalesced)."""
        eng = self.engine
        with eng._cv:
            self._check_open_locked()
            eng._ensure_thread_locked()
            while self._inflight >= self.max_inflight:
                self._check_open_locked()
                eng._cv.wait(1.0)
            self._check_open_locked()
            self._inflight += 1
            fut: Future = Future()
            eng._pending.append(
                _Submission(self, list(traces), None if scales is None else list(scales), fold, fut)
            )
            eng._cv.notify_all()
        return fut

    def flush(self) -> None:
        """Block until every submitted batch of this handle is folded, then
        re-raise the first recorded error (once).  Dropped-batch counters
        persist — the raised error announces the truncation, the counters
        let later readers detect it."""
        eng = self.engine
        with eng._cv:
            while self._inflight > 0:
                if eng._broken:
                    raise RuntimeError("analysis engine dispatcher died")
                eng._cv.wait(1.0)
            err, self._error = self._error, None
        if err is not None:
            raise err

    def close(self) -> None:
        """Drain and release the handle (idempotent).  The engine — and its
        dispatcher thread — stays up for other sessions; closing a handle
        only forbids further submissions on it."""
        try:
            with self.engine._cv:
                closed = self._closed
            if not closed:
                self.flush()
        finally:
            with self.engine._cv:
                self._closed = True
                self.engine._cv.notify_all()

    # -- dispatcher-side helpers -------------------------------------------- #

    def _check_open_locked(self) -> None:
        if self._closed:
            raise RuntimeError(
                "engine handle is closed — submit() after close() would "
                "enqueue work no dispatcher will ever drain"
            )
        if self.engine._closed:
            raise RuntimeError("analysis engine is closed")
        if self.engine._broken:
            raise RuntimeError("analysis engine dispatcher died")

    def _analyze(self, traces, scales, stager) -> DelayBreakdown:
        """Solo analysis of one batch (coalesced groups go through
        :meth:`EpochAnalyzer.analyze_batch_multi` instead)."""
        return analyze_any(self.analyzer, traces, scales, stager=stager)

    def _record_error_locked(self, err: BaseException, n_epochs: int) -> None:
        self.dropped_batches += 1
        self.dropped_epochs += int(n_epochs)
        if self._error is None:
            self._error = err


class EngineClient:
    """Handle-lifecycle plumbing shared by every session type that folds
    through the engine (``AttachedProgram``, ``FabricSession``).

    Subclasses provide ``_handle`` (an :class:`EngineHandle` or ``None``
    for synchronous sessions), ``_report_lock`` and ``_report`` (any
    object with ``dropped_batches`` / ``dropped_epochs`` fields)."""

    _handle: Optional[EngineHandle] = None
    # the report belongs to the session's lock; the handle's drop counters
    # belong to the engine's — _sync_dropped bridges them (never nested)
    _simlint_guards = guarded_by("_report_lock", "_report") | guarded_by(
        "_cv", "_handle.dropped_batches", "_handle.dropped_epochs"
    )

    def flush(self) -> None:
        """Block until every submitted batch has been analyzed and folded.

        Re-raises the first analyzer failure (once); the failed batch's
        epochs stay recorded as ``report.dropped_batches`` /
        ``dropped_epochs`` so truncated totals remain detectable."""
        if self._handle is None:
            return
        try:
            self._handle.flush()
        finally:
            self._sync_dropped()

    def close(self) -> None:
        """Flush and release the engine handle (idempotent).  The shared
        engine's dispatcher thread stays up for other sessions — closing a
        session never parks or leaks a thread."""
        if self._handle is None:
            return
        try:
            self._handle.close()
        finally:
            self._sync_dropped()

    def _sync_dropped(self) -> None:
        # the drop counters are dispatcher-written under the *engine's*
        # lock; snapshot them there, then publish under the report lock
        # (two disjoint critical sections — no nesting, no lock-order edge)
        with self._handle.engine._cv:
            dropped_batches = self._handle.dropped_batches
            dropped_epochs = self._handle.dropped_epochs
        with self._report_lock:
            self._report.dropped_batches = dropped_batches
            self._report.dropped_epochs = dropped_epochs

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class AnalysisEngine:
    """One dispatcher thread serving any number of attached sessions; see
    the module docstring.  ``coalesce=False`` disables cross-session
    stacking (every batch dispatches solo) — a debugging/bisection knob."""

    _simlint_guards = guarded_by(
        "_cv",
        "_pending",
        "_thread",
        "_closed",
        "_broken",
        "_active",
        "_stagers",
        "dispatches",
        "coalesced_dispatches",
        "max_coalesced_sessions",
        "_inflight",
    ) | guarded_by("_default_lock", "_default")

    def __init__(
        self,
        name: str = "cxlmemsim-engine",
        coalesce: bool = True,
        mesh=None,
    ):
        self.name = name
        self.coalesce = bool(coalesce)
        # a ('data',) mesh shards every coalesced dispatch's session axis
        # across devices (repro.launch.mesh.make_data_mesh); None = the
        # analyzer's own mesh (if any), i.e. single-device by default
        self.mesh = mesh
        self._cv = threading.Condition(threading.Lock())
        self._pending: Deque[_Submission] = deque()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._broken = False
        self._active = 0  # dispatches currently executing (guarded by _cv)
        self._stagers: Dict[np.dtype, EventStager] = {}
        # observability (read-only; updated under _cv)
        self.dispatches = 0
        self.coalesced_dispatches = 0
        self.max_coalesced_sessions = 1

    # -- lifecycle ----------------------------------------------------------- #

    _default_lock = threading.Lock()
    _default: Optional["AnalysisEngine"] = None

    @classmethod
    def default(cls) -> "AnalysisEngine":
        """The lazily-created process-wide engine: one daemon dispatcher
        shared by every session that doesn't bring its own engine.  A
        closed — or crashed — default engine is replaced, so one
        dispatcher death never disables async analysis for the rest of
        the process (already-registered handles keep raising; new
        sessions get a fresh engine)."""
        with cls._default_lock:
            d = cls._default
            # reading another engine's _closed/_broken without ITS _cv is a
            # benign race: a stale value only defers replacement by one call
            if d is None or d._closed or d._broken:  # simlint: ignore[lock-discipline] -- benign race: stale _closed/_broken only delays replacing the default engine one call
                cls._default = cls()
            return cls._default

    def register(self, analyzer, max_inflight: int = 2) -> EngineHandle:
        """Attach a session's analyzer; returns its :class:`EngineHandle`.

        ``analyzer`` is an :class:`~repro.core.analyzer.EpochAnalyzer`
        (coalescible when ``impl='inline'``) or any object with ``.flat``
        and ``.simulate`` (dispatched solo)."""
        with self._cv:
            if self._closed:
                raise RuntimeError("analysis engine is closed")
        return EngineHandle(self, analyzer, dispatch_key(analyzer), max_inflight)

    def flush(self) -> None:
        """Block until the queue is empty and no dispatch is running.
        Per-handle errors stay with their handles (``handle.flush``)."""
        with self._cv:
            while self._pending or self._active:
                if self._broken:
                    raise RuntimeError("analysis engine dispatcher died")
                self._cv.wait(1.0)

    def close(self) -> None:
        """Drain outstanding work, stop the dispatcher, join it (idempotent)."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
            thread = self._thread
        if (
            thread is not None
            and thread.is_alive()
            and thread is not threading.current_thread()
        ):
            thread.join()

    def __enter__(self) -> "AnalysisEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- dispatcher ---------------------------------------------------------- #

    def _ensure_thread_locked(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._worker, name=self.name, daemon=True
            )
            self._thread.start()

    @single_threaded("dispatcher-thread only: called from _launch, and the "
                     "engine runs exactly one dispatcher")
    def _stager_for(self, analyzer) -> Optional[EventStager]:
        if not isinstance(analyzer, EpochAnalyzer):
            return None
        dt = np.dtype(jnp.dtype(analyzer.dtype).name)
        st = self._stagers.get(dt)
        if st is None:
            # slots=2: the dispatcher overlaps batch k+1's staging/H2D with
            # batch k's compute, so staging must rotate to a fresh buffer
            # slot while the previous slot's planes may still back an
            # in-flight transfer
            st = self._stagers[dt] = EventStager(dt, slots=2)
        return st

    def _pop_group_locked(self) -> List[_Submission]:
        """FIFO head plus, when coalescing, the first pending submission of
        every *other* same-key handle.  Same-handle batches never share a
        dispatch (bit-stability of the solo path; per-handle FIFO order)."""
        first = self._pending.popleft()
        group = [first]
        if self.coalesce and first.handle.key is not None:
            taken = {id(first.handle)}
            kept: Deque[_Submission] = deque()
            while self._pending:
                sub = self._pending.popleft()
                if sub.handle.key == first.handle.key and id(sub.handle) not in taken:
                    taken.add(id(sub.handle))
                    group.append(sub)
                else:
                    kept.append(sub)
            self._pending = kept
        return group

    def _worker(self) -> None:
        # Depth-1 software pipeline: after launching a dispatch, the worker
        # does NOT block on its result — it first pops and launches the next
        # group (staging + H2D + async device dispatch), so batch k+1's host
        # work overlaps batch k's device compute.  The previous dispatch is
        # finished (device_get, folds, future resolution) only once the next
        # one is in flight, or immediately when the queue drains, so a lone
        # submission never waits on a successor.
        pend: Optional[_Launched] = None
        try:
            while True:
                group = None
                with self._cv:
                    if pend is None:
                        while not self._pending and not self._closed:
                            self._cv.wait(1.0)
                    if self._pending:
                        group = self._pop_group_locked()
                        self._active += 1
                    elif pend is None and self._closed:
                        return  # closed and drained
                if group is not None:
                    launched = self._launch(group)
                    if pend is not None:
                        self._finish(pend)
                    pend = launched
                else:
                    self._finish(pend)
                    pend = None
        except BaseException:
            with self._cv:
                self._broken = True
                self._cv.notify_all()
            raise

    def _launch(self, group: List[_Submission]) -> "_Launched":
        """Stage, transfer and launch one group without blocking on results.

        Solo :class:`EpochAnalyzer` submissions launch asynchronously
        (:meth:`EpochAnalyzer.launch_batch`); DES analyzers and coalesced
        stacks compute synchronously here and carry finished breakdowns.
        Never raises — a launch failure is carried in the returned record
        and surfaced by :meth:`_finish`."""
        stager = self._stager_for(group[0].handle.analyzer)
        live = group
        t0 = time.perf_counter()
        try:
            if len(group) > 1:
                # per-session validation BEFORE stacking: one session's bad
                # trace (unreachable route, scales mismatch) must drop only
                # that session's batch, never its coalesced peers'
                live = []
                for sub in group:
                    try:
                        sub.handle.analyzer._clean_pairs(sub.traces, sub.scales)
                    except BaseException as e:
                        with self._cv:
                            sub.handle._record_error_locked(e, len(sub.traces))
                        self._resolve(sub.future, error=e)
                    else:
                        live.append(sub)
            pending: Optional[PendingBatch] = None
            bds: Optional[List[DelayBreakdown]] = None
            if not live:
                bds = []
            elif (
                len(live) == 1
                and isinstance(live[0].handle.analyzer, EpochAnalyzer)
                and type(live[0].handle.analyzer).analyze_batch
                is EpochAnalyzer.analyze_batch
            ):
                # the overlapped fast path talks to launch_batch directly;
                # subclasses that override analyze_batch (tests inject
                # failures there) keep the classic synchronous route
                sub = live[0]
                pending = sub.handle.analyzer.launch_batch(
                    sub.traces, sub.scales, stager=stager
                )
            elif len(live) == 1:
                sub = live[0]
                bds = [sub.handle._analyze(sub.traces, sub.scales, stager)]
            else:
                bds = live[0].handle.analyzer.analyze_batch_multi(
                    [s.traces for s in live],
                    [s.scales for s in live],
                    stager=stager,
                    mesh=self.mesh,
                )
            return _Launched(
                group, live, pending, bds, time.perf_counter() - t0, None
            )
        except BaseException as e:
            return _Launched(group, live, None, None, time.perf_counter() - t0, e)

    def _finish(self, launched: "_Launched") -> None:
        """Resolve one launched group: block on the device result if it was
        an overlapped launch, run folds, resolve futures, release inflight
        slots."""
        group, live = launched.group, launched.live
        try:
            if launched.error is not None:
                raise launched.error
            t0 = time.perf_counter()
            if launched.pending is not None:
                bds: List[DelayBreakdown] = [launched.pending.finish()]
            else:
                bds = launched.bds
            # launch work + exposed finish wait; the overlap gap (spent
            # launching the NEXT group) is deliberately excluded
            elapsed = launched.launch_s + (time.perf_counter() - t0)
            if live:
                # written before the fold loop so fold callbacks (and any
                # reader after the future resolves) see this dispatch's
                # sharding stats on their own handle, even when a peer's
                # analyzer ran the stacked dispatch
                stats = getattr(
                    live[0].handle.analyzer, "last_dispatch", None
                )
                for sub in live:
                    sub.handle.last_dispatch = stats
                    sub.handle.last_group_size = len(live)
            total_epochs = sum(len(s.traces) for s in live)
            with self._cv:
                if live:
                    self.dispatches += 1
                if len(live) > 1:
                    self.coalesced_dispatches += 1
                    self.max_coalesced_sessions = max(
                        self.max_coalesced_sessions, len(live)
                    )
            for sub, bd in zip(live, bds):
                # the dispatch's compute seconds are attributed across the
                # coalesced group by epoch share (evenly when all batches
                # are empty) so summed analyzer_s never exceeds real cost
                if len(live) == 1:
                    share = elapsed
                elif total_epochs:
                    share = elapsed * len(sub.traces) / total_epochs
                else:
                    share = elapsed / len(live)
                try:
                    if sub.fold is not None:
                        sub.fold(bd, share)
                    self._resolve(sub.future, result=bd)
                except BaseException as e:  # analyzed but not folded: dropped
                    with self._cv:
                        sub.handle._record_error_locked(e, len(sub.traces))
                    self._resolve(sub.future, error=e)
        except BaseException as e:  # whole dispatch failed: every live batch
            with self._cv:  # dropped (validation failures already recorded)
                for sub in live:
                    sub.handle._record_error_locked(e, len(sub.traces))
            for sub in live:
                self._resolve(sub.future, error=e)
        finally:
            with self._cv:
                self._active -= 1
                for sub in group:
                    sub.handle._inflight -= 1
                self._cv.notify_all()

    @staticmethod
    def _resolve(fut: Future, result=None, error=None) -> None:
        """Resolve a submission future, tolerating callers that cancelled
        it while pending — an externally-cancelled future must not take
        down the dispatcher (report folding already happened or the drop
        was already recorded; the future is only a notification)."""
        try:
            if error is None:
                fut.set_result(result)
            else:
                fut.set_exception(error)
        except InvalidStateError:
            pass

    def stats(self) -> Dict[str, int]:
        with self._cv:
            return {
                "dispatches": self.dispatches,
                "coalesced_dispatches": self.coalesced_dispatches,
                "max_coalesced_sessions": self.max_coalesced_sessions,
                "pending": len(self._pending),
            }
