"""Roofline analysis from dry-run compiled artifacts.

Three terms per (arch × shape × mesh), per the brief:

    compute_s    = HLO_FLOPs / peak_FLOP/s          (per chip: the compiled
                   module under SPMD is the per-device program)
    memory_s     = HLO_bytes / HBM_bw
    collective_s = collective_bytes / link_bw

``cost_analysis()`` provides FLOPs and bytes; collective bytes are parsed
from the compiled HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op's shapes are summed with ring-algorithm
traffic factors.

The same machinery doubles as the simulator's ICI model: a TPU pod's ICI
fabric is representable as a CXLMemSim topology (links = switches), which is
how the paper's technique and the roofline engine share one analyzer.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

from .tracer import HardwareModel, TPU_V5E
from .units import gbps_to_bytes_per_s

__all__ = [
    "RooflineTerms",
    "collective_bytes_from_hlo",
    "roofline_terms",
    "DTYPE_BYTES",
]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# shapes like  bf16[8,128,1024]{2,1,0}  or  f32[]  (layout braces optional)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# collective op line:  %name = <result-shapes> <opname>(
_COLL_RE = re.compile(
    r"=\s*(\(?[^)]*?\)?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(fragment: str) -> float:
    """Sum byte sizes of every dtype[shape] occurrence in ``fragment``."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(fragment):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]<=[total]
        return int(m.group(2))
    return default


def collective_bytes_from_hlo(
    hlo_text: str, default_group_size: int = 1
) -> Dict[str, float]:
    """Per-device bytes moved over the interconnect, by collective type.

    Ring-algorithm traffic factors on the *result* shape R with group size g:

      all-reduce          2·(g−1)/g · R       (R == operand)
      all-gather          (g−1)/g · R         (R is the gathered full size)
      reduce-scatter      (g−1) · R           (operand = g·R)
      all-to-all          (g−1)/g · R
      collective-permute  R
    """
    out: Dict[str, float] = {
        "all-reduce": 0.0,
        "all-gather": 0.0,
        "reduce-scatter": 0.0,
        "all-to-all": 0.0,
        "collective-permute": 0.0,
    }
    counts: Dict[str, int] = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_frag, opname = m.group(1), m.group(2)
        kind = opname.replace("-start", "")
        nbytes = _shape_bytes(result_frag)
        if nbytes <= 0:
            continue
        g = _group_size(line, default_group_size)
        if kind == "collective-permute":
            factor = 1.0  # pairwise: always moves the result bytes
        elif g <= 1:
            # single-participant collective moves nothing
            factor = 0.0
        elif kind == "all-reduce":
            factor = 2.0 * (g - 1) / g
        elif kind == "all-gather":
            factor = (g - 1) / g
        elif kind == "reduce-scatter":
            factor = float(g - 1)
        elif kind == "all-to-all":
            factor = (g - 1) / g
        else:  # pragma: no cover — exhaustive above
            factor = 1.0
        out[kind] += nbytes * factor
        counts[kind] += 1
    out["total"] = sum(out.values())
    out.update({f"n_{k}": float(v) for k, v in counts.items()})
    return out


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float  # 6·N·D (train) or 2·N·tokens (inference), per chip
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline lower bound on step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundant compute."""
        return self.model_flops / self.hlo_flops if self.hlo_flops > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / roofline bound — the score we report.

        = (MODEL_FLOPS/peak) / max(compute, memory, collective): how close the
        step would run to ideal hardware speed if it achieved its bound.
        """
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops / (self.hlo_flops / max(self.compute_s, 1e-30))
        return ideal / self.bound_s

    def as_dict(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_chips": self.n_chips,
        }


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    model_flops: float,
    n_chips: int,
    hw: HardwareModel = TPU_V5E,
) -> RooflineTerms:
    """All inputs are per-device quantities from the compiled SPMD module."""
    return RooflineTerms(
        compute_s=hlo_flops / hw.peak_flops,
        memory_s=hlo_bytes / gbps_to_bytes_per_s(hw.hbm_gbps),
        collective_s=collective_bytes / gbps_to_bytes_per_s(hw.ici_gbps),
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        model_flops=model_flops,
        n_chips=n_chips,
    )
