"""Memory-event traces and the region->pool allocation map.

The paper's Tracer has two halves:

  1. an *allocation* tracer (eBPF probes on mmap/sbrk/brk) that maintains a
     map from address ranges to memory pools, and
  2. an *event* tracer (PEBS) that samples memory operations.

Our JAX-native analogue: every logical tensor region of a step function
(weights, activations, KV cache, optimizer state, MoE experts, ...) is
registered with a :class:`RegionMap`; a placement policy assigns each region
to a pool.  Event traces are dense struct-of-arrays so the timing analyzer
can be fully vectorized.

Times inside a trace are **epoch-relative nanoseconds** (float).  Keeping
them epoch-relative bounds their magnitude (epochs are ms-scale), so float32
retains sub-ns resolution inside jitted analyzer code; totals are accumulated
host-side in float64.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "CACHELINE_BYTES",
    "PAGE_BYTES",
    "EventStager",
    "MemEvents",
    "Region",
    "RegionMap",
    "concat_events",
    "merge_host_traces",
    "split_by_host",
    "synthetic_trace",
]

CACHELINE_BYTES = 64
PAGE_BYTES = 4096
# synthetic-trace burst width as a *fraction* of the epoch (dimensionless
# tuning knob, not a ns conversion)
_BURST_SPREAD_FRAC = 1e-3


@dataclasses.dataclass(frozen=True)
class MemEvents:
    """A struct-of-arrays trace of memory events within one epoch.

    Attributes:
      t_ns:    [N] issue time, ns, relative to epoch start, non-decreasing
               not required (the analyzer sorts).
      pool:    [N] int32 pool index into the FlatTopology.
      bytes_:  [N] bytes moved by the event (a transaction may cover many
               cachelines; granularity is the policy's choice).
      is_write:[N] bool (writes may cost differently; coherency uses this).
      region:  [N] int32 region id (for migration/hotness accounting).
      weight:  [N] statistical multiplicity (1.0 exact; 1/rate under PEBS-style
               sampling so count-proportional delays stay unbiased).
      host:    [N] int32 attached-host index (0 for single-host simulation).
               In a shared-fabric session events from several hosts are merged
               onto one timeline; the analyzer routes each event through its
               (host, pool) pair so contention appears only at shared
               components.
      qos:     [N] int32 QoS class (0 = default / highest priority).  Switch
               arbiters running 'priority' or 'wfq' disciplines order their
               queues by this class; FIFO switches ignore it.
    """

    t_ns: np.ndarray
    pool: np.ndarray
    bytes_: np.ndarray
    is_write: np.ndarray
    region: np.ndarray
    weight: np.ndarray = None  # type: ignore[assignment]
    host: np.ndarray = None  # type: ignore[assignment]
    qos: np.ndarray = None  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.weight is None:
            object.__setattr__(self, "weight", np.ones((len(self.t_ns),), np.float64))
        if self.host is None:
            object.__setattr__(self, "host", np.zeros((len(self.t_ns),), np.int32))
        if self.qos is None:
            object.__setattr__(self, "qos", np.zeros((len(self.t_ns),), np.int32))
        n = len(self.t_ns)
        for f in ("pool", "bytes_", "is_write", "region", "weight", "host", "qos"):
            if len(getattr(self, f)) != n:
                raise ValueError(f"field {f} length mismatch")

    @property
    def n(self) -> int:
        return int(len(self.t_ns))

    @property
    def total_bytes(self) -> float:
        return float(self.bytes_.sum())

    def sorted_by_time(self) -> "MemEvents":
        # Monotone fast path: a stable argsort of a non-decreasing key is the
        # identity permutation, so an already-sorted trace (the tracer's
        # common case, and everything downstream of merge_host_traces) costs
        # one O(N) check instead of an argsort plus seven gathers.
        if self.n <= 1 or bool(np.all(self.t_ns[1:] >= self.t_ns[:-1])):
            return self
        order = np.argsort(self.t_ns, kind="stable")
        return self.take(order)

    def take(self, idx: np.ndarray) -> "MemEvents":
        return MemEvents(
            t_ns=self.t_ns[idx],
            pool=self.pool[idx],
            bytes_=self.bytes_[idx],
            is_write=self.is_write[idx],
            region=self.region[idx],
            weight=self.weight[idx],
            host=self.host[idx],
            qos=self.qos[idx],
        )

    def with_host(self, host: int) -> "MemEvents":
        """Copy with every event tagged as issued by ``host``."""
        return dataclasses.replace(
            self, host=np.full((self.n,), int(host), np.int32)
        )

    def with_qos(self, qos) -> "MemEvents":
        """Copy with events tagged as QoS class ``qos`` — a scalar (a
        tenant's whole trace usually shares one class) or a per-event
        array."""
        q = np.asarray(qos, np.int32)
        if q.ndim == 0:
            q = np.full((self.n,), int(q), np.int32)
        elif q.shape != (self.n,):
            raise ValueError(f"qos shape {q.shape} != ({self.n},)")
        return dataclasses.replace(self, qos=q)

    def sample(self, rate: float, seed: int = 0) -> "MemEvents":
        """PEBS-style sampling: keep each event with probability ``rate`` and
        scale bytes by 1/rate so aggregate traffic is preserved in expectation.
        """
        if not (0.0 < rate <= 1.0):
            raise ValueError("rate must be in (0, 1]")
        if rate == 1.0:
            return self
        rng = np.random.default_rng(seed)
        keep = rng.random(self.n) < rate
        out = self.take(np.nonzero(keep)[0])
        return dataclasses.replace(
            out, bytes_=out.bytes_ / rate, weight=out.weight / rate
        )

    @staticmethod
    def empty() -> "MemEvents":
        z = np.zeros((0,))
        return MemEvents(
            t_ns=z.astype(np.float64),
            pool=z.astype(np.int32),
            bytes_=z.astype(np.float64),
            is_write=z.astype(bool),
            region=z.astype(np.int32),
        )

    @staticmethod
    def build(
        t_ns: Iterable[float],
        pool: Iterable[int],
        bytes_: Iterable[float],
        is_write: Optional[Iterable[bool]] = None,
        region: Optional[Iterable[int]] = None,
        host: Optional[Iterable[int]] = None,
        qos: Optional[Iterable[int]] = None,
    ) -> "MemEvents":
        t = _as_column(t_ns, np.float64)
        p = _as_column(pool, np.int32)
        b = _as_column(bytes_, np.float64)
        w = (
            _as_column(is_write, bool)
            if is_write is not None
            else np.zeros(len(t), bool)
        )
        r = (
            _as_column(region, np.int32)
            if region is not None
            else np.zeros(len(t), np.int32)
        )
        h = (
            _as_column(host, np.int32)
            if host is not None
            else np.zeros(len(t), np.int32)
        )
        q = (
            _as_column(qos, np.int32)
            if qos is not None
            else np.zeros(len(t), np.int32)
        )
        return MemEvents(t, p, b, w, r, host=h, qos=q)


def _as_column(x, dtype) -> np.ndarray:
    """Coerce a build() input to a 1-D array without the list round-trip.

    ndarrays and plain sequences go straight to ``np.asarray`` (an O(copy)
    conversion, or free when dtype already matches); only true generators are
    materialized first.
    """
    if not isinstance(x, (np.ndarray, list, tuple)):
        x = list(x)
    return np.asarray(x, dtype)


def concat_events(traces: Sequence[MemEvents]) -> MemEvents:
    traces = [t for t in traces if t.n]
    if not traces:
        return MemEvents.empty()
    return MemEvents(
        t_ns=np.concatenate([t.t_ns for t in traces]),
        pool=np.concatenate([t.pool for t in traces]),
        bytes_=np.concatenate([t.bytes_ for t in traces]),
        is_write=np.concatenate([t.is_write for t in traces]),
        region=np.concatenate([t.region for t in traces]),
        weight=np.concatenate([t.weight for t in traces]),
        host=np.concatenate([t.host for t in traces]),
        qos=np.concatenate([t.qos for t in traces]),
    )


def merge_host_traces(
    traces: Sequence[MemEvents],
    hosts: Optional[Sequence[int]] = None,
) -> MemEvents:
    """Merge per-host epoch traces onto one shared fabric timeline.

    ``traces[i]`` is tagged with host ``hosts[i]`` (default: index ``i``) and
    the union is returned time-sorted, which is exactly the analyzer's staging
    contract: co-scheduled epochs start at the same fabric instant, so their
    epoch-relative times are directly comparable.
    """
    if hosts is None:
        hosts = range(len(traces))
    tagged = [tr.with_host(h) for tr, h in zip(traces, hosts)]
    return concat_events(tagged).sorted_by_time()


def split_by_host(trace: MemEvents, n_hosts: int) -> List[MemEvents]:
    """Inverse of :func:`merge_host_traces`: per-host sub-traces, order kept."""
    return [
        trace.take(np.nonzero(trace.host == h)[0]) for h in range(int(n_hosts))
    ]


# --------------------------------------------------------------------------- #
# Batched staging buffers — the analyzer's host-side feed path
# --------------------------------------------------------------------------- #


def _bucket_pow2(n: int, floor: int) -> int:
    v = max(int(floor), 1)
    while v < n:
        v *= 2
    return v


class EventStager:
    """Reusable host staging buffers for bucketed, batched epoch analysis.

    The epoch analyzer pads traces up to power-of-two buckets so repeated
    calls hit the jit compile cache.  Doing that with ``np.pad`` allocates
    five fresh float64 arrays per epoch; at analyzer rates (thousands of
    epochs per second) the allocator churn dominates.  The stager instead
    owns one buffer set per ``(batch, length)`` bucket and refills it in
    place — steady-state staging performs zero host allocations, and the
    float64 -> analyzer-dtype conversion happens once, during the fill.

    Not thread-safe: every thread that stages must own its stager.  The
    shared :class:`~repro.core.engine.AnalysisEngine` owns one stager set
    per engine (all staging happens on its single dispatcher thread);
    each :class:`~repro.core.analyzer.EpochAnalyzer` keeps a private
    stager for callers analyzing synchronously on their own thread —
    the two never share buffers.
    """

    _FIELDS = ("t", "pool", "bytes", "weight", "host", "qos", "valid")

    # dispatches a bucket's natural caps must sit at (or below) half the
    # sticky high-water mark before the sticky caps shrink to the recent
    # peak — a transient burst stops pinning peak-size staging planes (and
    # their AOT executables) after this many consecutive idle calls
    CAP_DECAY_CALLS = 8

    def __init__(self, time_dtype: object = np.float32, slots: int = 1) -> None:
        self.time_dtype = np.dtype(time_dtype)
        # ``slots`` > 1 turns each bucket's buffer set into a ring: every
        # stage() call rotates to the next slot before filling, so a caller
        # overlapping H2D/compute of dispatch k with the staging of k+1
        # (the engine's double-buffered pipeline) never overwrites host
        # planes an in-flight transfer may still be reading.
        self.slots = max(1, int(slots))
        self._bufs: Dict[Tuple[int, int, int], Dict[str, np.ndarray]] = {}
        self._turn: Dict[Tuple[int, int], int] = {}
        self._pack_bufs: Dict[Tuple[int, int, int], Dict[str, np.ndarray]] = {}
        self._stack_bufs: Dict[Tuple[int, int, int], Dict[str, np.ndarray]] = {}
        self._stack_filled: Dict[Tuple[int, int, int], int] = {}
        self._cap_hwm: Dict[Tuple[int, int, int], Tuple[int, ...]] = {}
        # idle-decay state per cap key: consecutive calls whose natural caps
        # sat at <= half the sticky high-water mark, and the elementwise peak
        # of the natural caps observed during that streak
        self._cap_slack: Dict[Tuple[int, int, int], int] = {}
        self._cap_peak: Dict[Tuple[int, int, int], Tuple[int, ...]] = {}

    def rotate(self, b_bucket: int, n_bucket: int) -> int:
        """Advance this bucket's ring and return the now-current slot."""
        key = (b_bucket, n_bucket)
        slot = (self._turn.get(key, self.slots - 1) + 1) % self.slots
        self._turn[key] = slot
        return slot

    def buffers(self, b_bucket: int, n_bucket: int) -> Dict[str, np.ndarray]:
        key = (b_bucket, n_bucket, self._turn.get((b_bucket, n_bucket), 0))
        buf = self._bufs.get(key)
        if buf is None:
            buf = {
                "t": np.zeros((b_bucket, n_bucket), self.time_dtype),
                "pool": np.zeros((b_bucket, n_bucket), np.int32),
                "bytes": np.zeros((b_bucket, n_bucket), self.time_dtype),
                "weight": np.zeros((b_bucket, n_bucket), self.time_dtype),
                "host": np.zeros((b_bucket, n_bucket), np.int32),
                "qos": np.zeros((b_bucket, n_bucket), np.int32),
                "valid": np.zeros((b_bucket, n_bucket), bool),
                "span": np.zeros((b_bucket,), np.float64),
            }
            self._bufs[key] = buf
        return buf

    def stage(
        self, traces: Sequence["MemEvents"], b_bucket: int, n_bucket: int
    ) -> Dict[str, np.ndarray]:
        """Fill (in place) and return the buffer set for this bucket.

        Every row is delivered **time-sorted** — the analyzer's one stable
        sort per epoch happens here, on the host, and only when a trace is
        not already monotone (the tracer emits sorted epochs, so the common
        case is a 30 µs check plus plain copies).  Rows beyond
        ``len(traces)`` — and the tail of every row beyond its trace's
        event count — are marked invalid; ``span`` holds each epoch's max
        issue time + 1 (0 for empty rows).
        """
        if len(traces) > b_bucket:
            raise ValueError(f"{len(traces)} traces exceed batch bucket {b_bucket}")
        self.rotate(b_bucket, n_bucket)
        buf = self.buffers(b_bucket, n_bucket)
        self._fill_rows(buf, traces, b_bucket)
        return buf

    def _pack_buffers(self, b_bucket: int, width: int) -> Dict[str, np.ndarray]:
        key = (b_bucket, width, self._turn.get((b_bucket, width), 0))
        buf = self._pack_bufs.get(key)
        if buf is None:
            buf = {
                "t": np.zeros((b_bucket, width), self.time_dtype),
                "idx": np.zeros((b_bucket, width), np.int32),
            }
            self._pack_bufs[key] = buf
        return buf

    def stage_packed(
        self,
        traces: Sequence["MemEvents"],
        b_bucket: int,
        n_bucket: int,
        enter_stage: np.ndarray,
        n_stages: int,
        cap_floor: int = 16,
    ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray], Tuple[int, ...]]:
        """Pipeline staging: the full planes of :meth:`stage` plus per-stage
        packed ``(t, idx)`` planes feeding the device-resident chain cascade.

        ``enter_stage[pool]`` gives the cascade stage position at which an
        event routed to ``pool`` first enters the fabric (-1 = local, never
        routed).  Because every staged row is time-sorted and extracting a
        per-stage subsequence preserves that order, each packed segment is
        already a sorted run — the merge into one fabric timeline happens on
        device, with **zero host argsort** beyond the monotone check of
        :meth:`_fill_rows`.  Segment ``p`` occupies ``caps[p]`` slots (a
        power-of-two bucket of the batch-max count, shared across rows so
        the packed width is static per dispatch); pad slots carry
        ``t=+inf, idx=-1`` and sort harmlessly to every merge's tail.
        ``idx`` values are positions into the staged (sorted) full row.
        """
        if len(traces) > b_bucket:
            raise ValueError(f"{len(traces)} traces exceed batch bucket {b_bucket}")
        self.rotate(b_bucket, n_bucket)
        buf = self.buffers(b_bucket, n_bucket)
        self._fill_rows(buf, traces, b_bucket)
        enter = np.asarray(enter_stage, np.int32)
        n_stages = int(n_stages)
        counts = np.zeros((max(len(traces), 1), n_stages), np.int64)
        depth_rows: List[np.ndarray] = []
        for row, ev in enumerate(traces):
            d = enter[buf["pool"][row, : ev.n]]
            depth_rows.append(d)
            routed = d >= 0
            if routed.any():
                counts[row, :] = np.bincount(d[routed], minlength=n_stages)
        caps = tuple(
            _bucket_pow2(int(counts[:, p].max()), cap_floor)
            for p in range(n_stages)
        )
        # sticky caps: hold the high-water mark within a (batch, length)
        # bucket, so the packed width — and with it the AOT executable key —
        # stabilizes after the first few dispatches instead of flapping with
        # each epoch's depth distribution (zero steady-state recompiles).
        # Idle decay: once CAP_DECAY_CALLS consecutive calls need at most
        # half the held caps, shrink to the peak demand of that streak —
        # a one-off burst stops pinning peak-size planes forever, while a
        # workload oscillating around the mark never shrinks (each touch of
        # the high caps resets the streak, so decay costs at most one
        # recompile per genuine regime change.)
        cap_key = (b_bucket, n_bucket, n_stages)
        natural = caps
        prev = self._cap_hwm.get(cap_key)
        if prev is not None:
            idle = all(
                n <= p // 2 or p <= cap_floor
                for n, p in zip(natural, prev)
            )
            if idle:
                peak = self._cap_peak.get(cap_key, natural)
                peak = tuple(max(a, b) for a, b in zip(peak, natural))
                streak = self._cap_slack.get(cap_key, 0) + 1
                if streak >= self.CAP_DECAY_CALLS:
                    caps = tuple(max(c, cap_floor) for c in peak)
                    self._cap_slack[cap_key] = 0
                    self._cap_peak.pop(cap_key, None)
                else:
                    caps = prev
                    self._cap_slack[cap_key] = streak
                    self._cap_peak[cap_key] = peak
            else:
                caps = tuple(max(c, p) for c, p in zip(natural, prev))
                self._cap_slack[cap_key] = 0
                self._cap_peak.pop(cap_key, None)
        self._cap_hwm[cap_key] = caps
        width = int(sum(caps))
        self._turn[(b_bucket, width)] = self._turn.get((b_bucket, n_bucket), 0)
        pack = self._pack_buffers(b_bucket, width)
        pack["t"].fill(np.inf)
        pack["idx"].fill(-1)
        for row, d in enumerate(depth_rows):
            off = 0
            for p in range(n_stages):
                sel = np.flatnonzero(d == p)
                m = sel.shape[0]
                pack["t"][row, off : off + m] = buf["t"][row, sel]
                pack["idx"][row, off : off + m] = sel
                off += caps[p]
        return buf, pack, caps

    @staticmethod
    def _fill_rows(
        buf: Dict[str, np.ndarray], traces: Sequence["MemEvents"], b_bucket: int
    ) -> None:
        """Fill one ``[B, N]`` buffer view (shared by :meth:`stage` and the
        per-session planes of :meth:`stage_stack`)."""
        for row in range(b_bucket):
            ev = traces[row] if row < len(traces) else None
            n = ev.n if ev is not None else 0
            if n:
                if np.all(ev.t_ns[1:] >= ev.t_ns[:-1]):
                    t, pool, nbytes, weight, host, qos = (
                        ev.t_ns, ev.pool, ev.bytes_, ev.weight, ev.host, ev.qos
                    )
                else:
                    order = np.argsort(ev.t_ns, kind="stable")
                    t, pool, nbytes, weight, host, qos = (
                        ev.t_ns[order], ev.pool[order], ev.bytes_[order],
                        ev.weight[order], ev.host[order], ev.qos[order],
                    )
                buf["t"][row, :n] = t
                buf["pool"][row, :n] = pool
                buf["bytes"][row, :n] = nbytes
                buf["weight"][row, :n] = weight
                buf["host"][row, :n] = host
                buf["qos"][row, :n] = qos
                buf["valid"][row, :n] = True
                buf["span"][row] = float(t[-1]) + 1.0
            else:
                buf["span"][row] = 0.0
            buf["t"][row, n:] = 0.0
            buf["pool"][row, n:] = 0
            buf["bytes"][row, n:] = 0.0
            buf["weight"][row, n:] = 0.0
            buf["host"][row, n:] = 0
            buf["qos"][row, n:] = 0
            buf["valid"][row, n:] = False

    def stack_buffers(
        self, k_bucket: int, b_bucket: int, n_bucket: int
    ) -> Dict[str, np.ndarray]:
        key = (k_bucket, b_bucket, n_bucket)
        buf = self._stack_bufs.get(key)
        if buf is None:
            flat = self.buffers(b_bucket, n_bucket)  # dtype source of truth
            buf = {
                f: np.zeros((k_bucket,) + flat[f].shape, flat[f].dtype)
                for f in self._FIELDS + ("span",)
            }
            self._stack_bufs[key] = buf
        return buf

    def stage_stack(
        self,
        groups: Sequence[Sequence["MemEvents"]],
        k_bucket: int,
        b_bucket: int,
        n_bucket: int,
    ) -> Dict[str, np.ndarray]:
        """Fill (in place) and return ``[K, B, N]`` buffers: one plane per
        epoch batch, each staged under the exact :meth:`stage` contract —
        the shared engine's cross-session coalescing path.  Planes beyond
        ``len(groups)`` are all-invalid; only planes a previous (larger)
        fill dirtied are re-cleared, and clearing touches just the masks
        the analyzer reads (``valid``/``span``) — stale payload values
        under an invalid mask are never observable."""
        if len(groups) > k_bucket:
            raise ValueError(f"{len(groups)} groups exceed stack bucket {k_bucket}")
        for g in groups:
            if len(g) > b_bucket:
                raise ValueError(f"{len(g)} traces exceed batch bucket {b_bucket}")
        key = (k_bucket, b_bucket, n_bucket)
        buf = self.stack_buffers(*key)
        for k, traces in enumerate(groups):
            plane = {f: buf[f][k] for f in self._FIELDS + ("span",)}
            self._fill_rows(plane, traces, b_bucket)
        for k in range(len(groups), self._stack_filled.get(key, 0)):
            buf["valid"][k] = False
            buf["span"][k] = 0.0
        self._stack_filled[key] = len(groups)
        return buf


# --------------------------------------------------------------------------- #
# Region map — the eBPF allocation-trace analogue
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class Region:
    """A logical allocation (tensor class or individual buffer)."""

    rid: int
    name: str
    nbytes: int
    tensor_class: str  # 'param' | 'grad' | 'opt_state' | 'activation' | 'kvcache' | 'expert' | 'input' | 'other'
    pool: int = 0  # pool index; set by a placement policy
    access_count: float = 0.0  # running hotness statistic (per epoch window)


class RegionMap:
    """Maps logical regions to pools — the software analogue of the paper's
    eBPF-maintained address-range map.

    ``alloc`` corresponds to tracing mmap/sbrk/brk; ``free`` to munmap.
    Placement policies (:mod:`repro.core.policy`) mutate ``Region.pool``.
    """

    def __init__(self) -> None:
        self._regions: List[Region] = []
        self._by_name: Dict[str, Region] = {}

    def alloc(self, name: str, nbytes: int, tensor_class: str = "other", pool: int = 0) -> Region:
        if name in self._by_name:
            raise KeyError(f"region {name!r} already allocated")
        r = Region(rid=len(self._regions), name=name, nbytes=int(nbytes), tensor_class=tensor_class, pool=pool)
        self._regions.append(r)
        self._by_name[name] = r
        return r

    def free(self, name: str) -> None:
        r = self._by_name.pop(name)
        # keep rid slot (traces may still reference it); mark empty
        r.nbytes = 0

    def __getitem__(self, name: str) -> Region:
        return self._by_name[name]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __iter__(self) -> Iterator[Region]:
        return iter(self._regions)

    def __len__(self) -> int:
        return len(self._regions)

    @property
    def regions(self) -> List[Region]:
        return list(self._regions)

    def by_class(self, tensor_class: str) -> List[Region]:
        return [r for r in self._regions if r.tensor_class == tensor_class]

    def pool_of(self, name: str) -> int:
        return self._by_name[name].pool

    def pool_vector(self) -> np.ndarray:
        """[n_regions] int32: region id -> pool id (dense lookup table)."""
        out = np.zeros((len(self._regions),), np.int32)
        for r in self._regions:
            out[r.rid] = r.pool
        return out

    def bytes_per_pool(self, n_pools: int) -> np.ndarray:
        out = np.zeros((n_pools,), np.float64)
        for r in self._regions:
            out[r.pool] += r.nbytes
        return out

    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self._regions)


# --------------------------------------------------------------------------- #
# Synthetic traces (tests / microbenchmarks)
# --------------------------------------------------------------------------- #


def synthetic_trace(
    n_events: int,
    n_pools: int,
    epoch_ns: float = 1e6,
    granule_bytes: float = CACHELINE_BYTES,
    pool_probs: Optional[Sequence[float]] = None,
    write_frac: float = 0.3,
    seed: int = 0,
    burstiness: float = 0.0,
    n_qos_classes: int = 1,
    qos_probs: Optional[Sequence[float]] = None,
) -> MemEvents:
    """Random trace generator used by tests and the microbenchmark suite.

    ``burstiness`` in [0, 1): 0 => uniform issue times; near 1 => events
    clustered into bursts (stress for congestion/bandwidth modelling).
    ``n_qos_classes`` > 1 tags events with random QoS classes
    (``qos_probs`` weights the draw; uniform by default).
    """
    rng = np.random.default_rng(seed)
    if pool_probs is None:
        pool_probs = np.full((n_pools,), 1.0 / n_pools)
    pool_probs = np.asarray(pool_probs, np.float64)
    pool_probs = pool_probs / pool_probs.sum()
    if burstiness > 0:
        n_bursts = max(1, int(n_events * (1 - burstiness) / 16) + 1)
        centers = rng.uniform(0, epoch_ns, size=n_bursts)
        t = rng.choice(centers, size=n_events) + rng.exponential(
            scale=max(epoch_ns * (1 - burstiness) * _BURST_SPREAD_FRAC, 1.0),
            size=n_events
        )
        t = np.clip(t, 0, epoch_ns)
    else:
        t = rng.uniform(0, epoch_ns, size=n_events)
    if n_qos_classes > 1:
        qp = (
            np.asarray(qos_probs, np.float64)
            if qos_probs is not None
            else np.full((n_qos_classes,), 1.0 / n_qos_classes)
        )
        qos = rng.choice(n_qos_classes, size=n_events, p=qp / qp.sum())
        qos = qos.astype(np.int32)
    else:
        qos = np.zeros((n_events,), np.int32)
    return MemEvents(
        t_ns=np.sort(t),
        pool=rng.choice(n_pools, size=n_events, p=pool_probs).astype(np.int32),
        bytes_=np.full((n_events,), float(granule_bytes)),
        is_write=rng.random(n_events) < write_frac,
        region=np.zeros((n_events,), np.int32),
        qos=qos,
    )
