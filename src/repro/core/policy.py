"""Placement policies: which regions live in which memory pool.

This is the research surface the paper says CXLMemSim enables ("memory
scheduling for complex applications", "comparison of cache-line and page
memory management").  A policy assigns every :class:`~repro.core.events.Region`
a pool; the tracer then emits events against those pools.

Policies are deliberately simple, composable objects so experiments can sweep
them (see ``examples/topology_explorer.py``).

Two assignment surfaces per policy:

  * :meth:`PlacementPolicy.place` — the historical per-``Region`` Python
    loop that mutates ``Region.pool`` in place.  Kept as the **parity
    oracle**: it is the executable specification each vectorized path is
    regression-tested against (``tests/test_scenario.py``).
  * :meth:`PlacementPolicy.assign` — vectorized assignment over a
    :class:`RegionArrays` snapshot, returning a ``[R]`` pool vector without
    touching any ``Region`` object.  :func:`assign_batch` stacks K policies
    into a ``[K, R]`` placement matrix (deduplicating repeated policy
    objects), which is what the scenario-sweep engine
    (:mod:`repro.core.scenario`) feeds to its stacked dispatch.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from .events import CACHELINE_BYTES, PAGE_BYTES, Region, RegionMap
from .topology import FlatTopology
from .units import bytes_to_gib

# tie-break epsilon for byte-share deficits (NOT a unit conversion)
_EPS_BYTES = 1e-9


__all__ = [
    "PlacementPolicy",
    "LocalOnlyPolicy",
    "ClassMapPolicy",
    "InterleavePolicy",
    "HotnessTieredPolicy",
    "RegionArrays",
    "assign_batch",
    "bytes_per_pool_batch",
    "capacity_check",
]


@dataclasses.dataclass(frozen=True)
class RegionArrays:
    """Struct-of-arrays snapshot of a :class:`~repro.core.events.RegionMap`.

    Policies' vectorized ``assign`` paths operate on these dense arrays so a
    K-scenario sweep pays one marshalling pass instead of K object walks.
    ``class_codes`` indexes ``class_names`` (the tensor-class vocabulary of
    this snapshot); ``names``/``access_count``/``nbytes`` are aligned by rid.
    """

    nbytes: np.ndarray  # [R] float64
    access_count: np.ndarray  # [R] float64 (hotness fallback input)
    class_codes: np.ndarray  # [R] int32 into class_names
    class_names: Tuple[str, ...]
    names: Tuple[str, ...]

    @property
    def n(self) -> int:
        return int(len(self.nbytes))

    @staticmethod
    def from_regions(regions: RegionMap) -> "RegionArrays":
        regs = list(regions)
        vocab: Dict[str, int] = {}
        codes = np.zeros((len(regs),), np.int32)
        for i, r in enumerate(regs):
            codes[i] = vocab.setdefault(r.tensor_class, len(vocab))
        return RegionArrays(
            nbytes=np.asarray([float(r.nbytes) for r in regs], np.float64),
            access_count=np.asarray([float(r.access_count) for r in regs], np.float64),
            class_codes=codes,
            class_names=tuple(vocab),
            names=tuple(r.name for r in regs),
        )

    def class_mask(self, classes) -> np.ndarray:
        """[R] bool: region's tensor class is in ``classes``."""
        in_vocab = np.asarray([c in classes for c in self.class_names], bool)
        return in_vocab[self.class_codes]


class PlacementPolicy:
    """Base: assigns pools to regions; granularity controls event batching.

    ``granularity_bytes`` is the transaction granule the tracer uses when it
    expands a logical access into events: 64 B cachelines model hardware
    (CXL-native) management; 4 KiB pages model software (OS) management.
    """

    name = "base"

    def __init__(self, granularity_bytes: int = CACHELINE_BYTES):
        if granularity_bytes <= 0:
            raise ValueError("granularity must be positive")
        self.granularity_bytes = int(granularity_bytes)

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        """Loop parity oracle: mutate ``Region.pool`` in place."""
        raise NotImplementedError

    def assign(self, ra: RegionArrays, flat: FlatTopology) -> np.ndarray:
        """Vectorized assignment: ``[R]`` int32 pool vector, no mutation.

        Must agree exactly with :meth:`place` on the same inputs (the loop
        is the specification; ``tests/test_scenario.py`` locks the parity).
        """
        raise NotImplementedError

    def with_granularity(self, granularity_bytes: int) -> "PlacementPolicy":
        """Copy of this policy with a different management granule — the
        sweep engine's granularity axis (placement logic unchanged)."""
        if granularity_bytes <= 0:
            raise ValueError("granularity must be positive")
        out = copy.copy(self)
        out.granularity_bytes = int(granularity_bytes)
        return out

    def assign_key(self) -> Optional[tuple]:
        """Hashable fingerprint of everything ``assign`` reads, or None.

        :func:`assign_batch` dedups on it, so policies that differ only in
        granularity (``with_granularity`` copies — the granule shapes the
        trace, never the placement) share one placement computation.
        ``None`` disables content dedup for the policy (object-identity
        dedup still applies)."""
        return None

    def describe(self) -> str:
        gran = "cacheline" if self.granularity_bytes == CACHELINE_BYTES else (
            "page" if self.granularity_bytes == PAGE_BYTES else f"{self.granularity_bytes}B"
        )
        return f"{self.name}(granularity={gran})"


class LocalOnlyPolicy(PlacementPolicy):
    """Everything in local DRAM — the native-execution baseline."""

    name = "local_only"

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        for r in regions:
            r.pool = 0

    def assign(self, ra: RegionArrays, flat: FlatTopology) -> np.ndarray:
        return np.zeros((ra.n,), np.int32)

    def assign_key(self):
        return (self.name,)


class ClassMapPolicy(PlacementPolicy):
    """Static mapping from tensor class to pool (by name).

    The canonical CXL experiments: ``{'opt_state': 'cxl_pool'}`` (optimizer
    offload), ``{'kvcache': 'cxl_pool'}`` (KV-cache offload),
    ``{'expert': 'cxl_pool'}`` (cold-expert offload for MoE).
    """

    name = "class_map"

    def __init__(
        self,
        class_to_pool: Mapping[str, str],
        granularity_bytes: int = CACHELINE_BYTES,
    ):
        super().__init__(granularity_bytes)
        self.class_to_pool = dict(class_to_pool)

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        for r in regions:
            target = self.class_to_pool.get(r.tensor_class)
            r.pool = name_to_idx[target] if target is not None else 0

    def assign(self, ra: RegionArrays, flat: FlatTopology) -> np.ndarray:
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        table = np.zeros((len(ra.class_names),), np.int32)
        for ci, cname in enumerate(ra.class_names):
            target = self.class_to_pool.get(cname)
            table[ci] = name_to_idx[target] if target is not None else 0
        return table[ra.class_codes]

    def assign_key(self):
        return (self.name, tuple(sorted(self.class_to_pool.items())))


class InterleavePolicy(PlacementPolicy):
    """Round-robin regions across a set of pools (weighted).

    Models NUMA-style interleaving across CXL expanders to spread bandwidth.

    Selection rule (deterministic): regions are visited in declaration
    order; each goes to the pool with the largest byte-share *deficit*
    ``w_k - placed_k / total_placed``.  **Ties resolve to the earliest pool
    in the declared ``pools`` sequence** — so the very first placement (all
    deficits equal to the normalized weights) seeds the max-weight pool,
    first-declared among equals, and an equal-weight, equal-size stream
    round-robins exactly in declaration order.  This contract is shared by
    the loop and vectorized paths and locked by ``tests/test_scenario.py``.
    """

    name = "interleave"

    def __init__(
        self,
        pools: Sequence[str],
        weights: Optional[Sequence[float]] = None,
        classes: Optional[Sequence[str]] = None,  # None => every class
        granularity_bytes: int = CACHELINE_BYTES,
    ):
        super().__init__(granularity_bytes)
        self.pools = list(pools)
        self.weights = list(weights) if weights is not None else [1.0] * len(self.pools)
        if len(self.weights) != len(self.pools):
            raise ValueError("weights/pools length mismatch")
        self.classes = set(classes) if classes is not None else None

    @staticmethod
    def _pick(deficit: np.ndarray) -> int:
        # np.argmax returns the FIRST maximum: ties deliberately resolve to
        # the earliest *declared* pool (deficit is indexed in declaration
        # order), which is the documented tie-breaking contract.
        return int(np.argmax(deficit))

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        idxs = [name_to_idx[p] for p in self.pools]
        w = np.asarray(self.weights, np.float64)
        w = w / w.sum()
        # deterministic weighted round-robin by cumulative byte share
        placed_bytes = np.zeros((len(idxs),), np.float64)
        for r in regions:
            if self.classes is not None and r.tensor_class not in self.classes:
                r.pool = 0
                continue
            total = placed_bytes.sum() + _EPS_BYTES
            deficit = w - placed_bytes / total
            k = self._pick(deficit)
            r.pool = idxs[k]
            placed_bytes[k] += r.nbytes

    def assign(self, ra: RegionArrays, flat: FlatTopology) -> np.ndarray:
        """Deficit round-robin without ``Region`` traffic.

        The deficit recurrence is inherently sequential in regions (each
        choice feeds the next deficit), so the vectorization here is across
        *pools* per step — and across whole scenarios in
        :func:`assign_batch`, where K interleave variants share one pass.
        """
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        idxs = np.asarray([name_to_idx[p] for p in self.pools], np.int32)
        w = np.asarray(self.weights, np.float64)
        w = w / w.sum()
        out = np.zeros((ra.n,), np.int32)
        sel = (
            np.flatnonzero(ra.class_mask(self.classes))
            if self.classes is not None
            else np.arange(ra.n)
        )
        placed_bytes = np.zeros((len(idxs),), np.float64)
        for i in sel:
            total = placed_bytes.sum() + _EPS_BYTES
            deficit = w - placed_bytes / total
            k = self._pick(deficit)
            out[i] = idxs[k]
            placed_bytes[k] += ra.nbytes[i]
        return out

    def assign_key(self):
        return (
            self.name,
            tuple(self.pools),
            tuple(self.weights),
            tuple(sorted(self.classes)) if self.classes is not None else None,
        )


class HotnessTieredPolicy(PlacementPolicy):
    """Hottest regions local until local capacity is exhausted; rest to the
    fallback pool — a static tiering oracle given access statistics.

    ``hotness`` maps region name -> access count (e.g. harvested from a prior
    profiled run via :class:`~repro.core.attach.CXLMemSim`).

    Packing is greedy **first-fit** in hotness-density order: a region that
    does not fit leaves the budget untouched, so a later (colder but
    smaller) region may still land local.
    """

    name = "hotness_tiered"

    def __init__(
        self,
        fallback_pool: str,
        hotness: Optional[Mapping[str, float]] = None,
        local_budget_bytes: Optional[int] = None,
        granularity_bytes: int = PAGE_BYTES,
    ):
        super().__init__(granularity_bytes)
        self.fallback_pool = fallback_pool
        self.hotness = dict(hotness or {})
        self.local_budget_bytes = local_budget_bytes

    def _budget(self, flat: FlatTopology) -> float:
        return (
            self.local_budget_bytes
            if self.local_budget_bytes is not None
            else int(flat.pool_capacity[0])
        )

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        fb = name_to_idx[self.fallback_pool]
        budget = self._budget(flat)
        # hotness density = accesses per byte; hottest-per-byte goes local first
        def density(r: Region) -> float:
            h = self.hotness.get(r.name, r.access_count)
            return h / max(r.nbytes, 1)

        used = 0
        for r in sorted(regions, key=density, reverse=True):
            if used + r.nbytes <= budget:
                r.pool = 0
                used += r.nbytes
            else:
                r.pool = fb

    def assign(self, ra: RegionArrays, flat: FlatTopology) -> np.ndarray:
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        fb = np.int32(name_to_idx[self.fallback_pool])
        budget = self._budget(flat)
        if self.hotness:
            h = np.asarray(
                [self.hotness.get(nm, ac) for nm, ac in zip(ra.names, ra.access_count)],
                np.float64,
            )
        else:
            h = ra.access_count
        density = h / np.maximum(ra.nbytes, 1)
        # stable sort on -density == sorted(..., reverse=True): density ties
        # keep declaration (rid) order, matching the loop oracle
        order = np.argsort(-density, kind="stable")
        b = ra.nbytes[order]
        accept = np.zeros((ra.n,), bool)
        # greedy first-fit: vectorized in runs — each pass accepts the
        # longest prefix that fits and skips the first overflowing region,
        # so the pass count is 1 + number of rejections (worst case O(R)
        # passes on adversarial big/small alternations; real region lists
        # reject a handful of tail regions)
        used, start = 0.0, 0
        while start < ra.n:
            csum = used + np.cumsum(b[start:])
            fit = csum <= budget
            if fit.all():
                accept[start:] = True
                break
            first_bad = int(np.argmin(fit))  # first False
            accept[start : start + first_bad] = True
            if first_bad > 0:
                used = float(csum[first_bad - 1])
            start += first_bad + 1
        out = np.full((ra.n,), fb, np.int32)
        out[order[accept]] = 0
        return out

    def assign_key(self):
        return (
            self.name,
            self.fallback_pool,
            tuple(sorted(self.hotness.items())),
            self.local_budget_bytes,
        )


# --------------------------------------------------------------------------- #
# Batched placement + capacity accounting (the sweep engine's feed path)
# --------------------------------------------------------------------------- #


def assign_batch(
    policies: Sequence[PlacementPolicy],
    ra: RegionArrays,
    flat: FlatTopology,
) -> np.ndarray:
    """``[K, R]`` placement matrix: row k is ``policies[k].assign(ra, flat)``.

    Rows dedup on :meth:`PlacementPolicy.assign_key` (falling back to
    object identity when a policy returns None), so a cartesian sweep that
    reuses one policy across every topology/cache/granularity variant —
    including ``with_granularity`` copies, whose placement is identical by
    construction — computes each distinct placement once and broadcasts.
    """
    out = np.empty((len(policies), ra.n), np.int32)
    computed: Dict[object, np.ndarray] = {}
    for k, p in enumerate(policies):
        key = p.assign_key()
        if key is None:
            key = id(p)
        row = computed.get(key)
        if row is None:
            row = p.assign(ra, flat)
            computed[key] = row
        out[k] = row
    return out


def bytes_per_pool_batch(assign: np.ndarray, nbytes: np.ndarray, n_pools: int) -> np.ndarray:
    """``[K, P]`` bytes placed per pool for a ``[K, R]`` placement matrix."""
    K = assign.shape[0]
    out = np.zeros((K, n_pools), np.float64)
    np.add.at(out, (np.arange(K)[:, None], assign), nbytes[None, :])
    return out


def capacity_check(regions: RegionMap, flat: FlatTopology) -> Dict[str, float]:
    """Bytes placed per pool vs capacity; raises on overflow."""
    per_pool = regions.bytes_per_pool(flat.n_pools)
    report = {}
    for i, name in enumerate(flat.pool_names):
        cap = float(flat.pool_capacity[i])
        report[name] = per_pool[i] / cap if cap > 0 else 0.0
        if per_pool[i] > cap:
            raise ValueError(
                f"pool {name} over capacity: {bytes_to_gib(per_pool[i]):.1f} GiB "
                f"placed, {bytes_to_gib(cap):.1f} GiB available"
            )
    return report
