"""Placement policies: which regions live in which memory pool.

This is the research surface the paper says CXLMemSim enables ("memory
scheduling for complex applications", "comparison of cache-line and page
memory management").  A policy assigns every :class:`~repro.core.events.Region`
a pool; the tracer then emits events against those pools.

Policies are deliberately simple, composable objects so experiments can sweep
them (see ``examples/topology_explorer.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence

import numpy as np

from .events import CACHELINE_BYTES, PAGE_BYTES, Region, RegionMap
from .topology import FlatTopology

__all__ = [
    "PlacementPolicy",
    "LocalOnlyPolicy",
    "ClassMapPolicy",
    "InterleavePolicy",
    "HotnessTieredPolicy",
    "capacity_check",
]


class PlacementPolicy:
    """Base: assigns pools to regions; granularity controls event batching.

    ``granularity_bytes`` is the transaction granule the tracer uses when it
    expands a logical access into events: 64 B cachelines model hardware
    (CXL-native) management; 4 KiB pages model software (OS) management.
    """

    name = "base"

    def __init__(self, granularity_bytes: int = CACHELINE_BYTES):
        if granularity_bytes <= 0:
            raise ValueError("granularity must be positive")
        self.granularity_bytes = int(granularity_bytes)

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        raise NotImplementedError

    def describe(self) -> str:
        gran = "cacheline" if self.granularity_bytes == CACHELINE_BYTES else (
            "page" if self.granularity_bytes == PAGE_BYTES else f"{self.granularity_bytes}B"
        )
        return f"{self.name}(granularity={gran})"


class LocalOnlyPolicy(PlacementPolicy):
    """Everything in local DRAM — the native-execution baseline."""

    name = "local_only"

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        for r in regions:
            r.pool = 0


class ClassMapPolicy(PlacementPolicy):
    """Static mapping from tensor class to pool (by name).

    The canonical CXL experiments: ``{'opt_state': 'cxl_pool'}`` (optimizer
    offload), ``{'kvcache': 'cxl_pool'}`` (KV-cache offload),
    ``{'expert': 'cxl_pool'}`` (cold-expert offload for MoE).
    """

    name = "class_map"

    def __init__(
        self,
        class_to_pool: Mapping[str, str],
        granularity_bytes: int = CACHELINE_BYTES,
    ):
        super().__init__(granularity_bytes)
        self.class_to_pool = dict(class_to_pool)

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        for r in regions:
            target = self.class_to_pool.get(r.tensor_class)
            r.pool = name_to_idx[target] if target is not None else 0


class InterleavePolicy(PlacementPolicy):
    """Round-robin regions across a set of pools (weighted).

    Models NUMA-style interleaving across CXL expanders to spread bandwidth.
    """

    name = "interleave"

    def __init__(
        self,
        pools: Sequence[str],
        weights: Optional[Sequence[float]] = None,
        classes: Optional[Sequence[str]] = None,  # None => every class
        granularity_bytes: int = CACHELINE_BYTES,
    ):
        super().__init__(granularity_bytes)
        self.pools = list(pools)
        self.weights = list(weights) if weights is not None else [1.0] * len(self.pools)
        if len(self.weights) != len(self.pools):
            raise ValueError("weights/pools length mismatch")
        self.classes = set(classes) if classes is not None else None

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        idxs = [name_to_idx[p] for p in self.pools]
        w = np.asarray(self.weights, np.float64)
        w = w / w.sum()
        # deterministic weighted round-robin by cumulative byte share
        placed_bytes = np.zeros((len(idxs),), np.float64)
        for r in regions:
            if self.classes is not None and r.tensor_class not in self.classes:
                r.pool = 0
                continue
            total = placed_bytes.sum() + 1e-9
            deficit = w - placed_bytes / total
            k = int(np.argmax(deficit))
            r.pool = idxs[k]
            placed_bytes[k] += r.nbytes


class HotnessTieredPolicy(PlacementPolicy):
    """Hottest regions local until local capacity is exhausted; rest to the
    fallback pool — a static tiering oracle given access statistics.

    ``hotness`` maps region name -> access count (e.g. harvested from a prior
    profiled run via :class:`~repro.core.attach.CXLMemSim`).
    """

    name = "hotness_tiered"

    def __init__(
        self,
        fallback_pool: str,
        hotness: Optional[Mapping[str, float]] = None,
        local_budget_bytes: Optional[int] = None,
        granularity_bytes: int = PAGE_BYTES,
    ):
        super().__init__(granularity_bytes)
        self.fallback_pool = fallback_pool
        self.hotness = dict(hotness or {})
        self.local_budget_bytes = local_budget_bytes

    def place(self, regions: RegionMap, flat: FlatTopology) -> None:
        name_to_idx = {n: i for i, n in enumerate(flat.pool_names)}
        fb = name_to_idx[self.fallback_pool]
        budget = (
            self.local_budget_bytes
            if self.local_budget_bytes is not None
            else int(flat.pool_capacity[0])
        )
        # hotness density = accesses per byte; hottest-per-byte goes local first
        def density(r: Region) -> float:
            h = self.hotness.get(r.name, r.access_count)
            return h / max(r.nbytes, 1)

        used = 0
        for r in sorted(regions, key=density, reverse=True):
            if used + r.nbytes <= budget:
                r.pool = 0
                used += r.nbytes
            else:
                r.pool = fb


def capacity_check(regions: RegionMap, flat: FlatTopology) -> Dict[str, float]:
    """Bytes placed per pool vs capacity; raises on overflow."""
    per_pool = regions.bytes_per_pool(flat.n_pools)
    report = {}
    for i, name in enumerate(flat.pool_names):
        cap = float(flat.pool_capacity[i])
        report[name] = per_pool[i] / cap if cap > 0 else 0.0
        if per_pool[i] > cap:
            raise ValueError(
                f"pool {name} over capacity: {per_pool[i] / 2**30:.1f} GiB "
                f"placed, {cap / 2**30:.1f} GiB available"
            )
    return report
