"""Expander-side device-DRAM cache model (CXL-DMSim-style, epoch-granular).

Real CXL expanders front their media (cheap DRAM, NV media, far memory)
with an on-device DRAM cache; CXL-DMSim (arXiv 2411.02282) validates that
this cache materially shifts effective access latency.  This module models
it at the same fidelity the rest of the simulator operates at — per epoch,
vectorized, no per-access sequential state machine:

  1. **Addresses.**  Traces carry (region, bytes), not addresses, so each
     region is given a contiguous line-aligned address range and a running
     byte cursor: successive events of a region stream through its range
     (wrapping), which makes a region's cache footprint its working-set
     size — small hot regions fit, large streaming regions thrash.
  2. **Tag array.**  Each cached pool owns a ``n_sets``-set,
     ``ways``-way tag array (``ways = capacity / (line_bytes * n_sets)``).
     Per epoch, the distinct lines touched in each set are ranked by
     weighted access count and the top ``ways`` are the epoch's resident
     set; sets with spare ways keep previously-resident lines.  An access
     hits iff its line is resident this epoch and is not the line's first
     touch from a non-resident start (the fill miss).  This is the
     epoch-granular analogue of LRU: within-epoch ordering is collapsed,
     exactly the fidelity trade the Timer makes for every other delay.
  3. **Latency scaling.**  Hits are charged the device-DRAM hit latency
     instead of the media latency; switches/RC are still traversed (the
     cache lives on the expander), so congestion and bandwidth delays are
     unchanged.  The per-epoch per-(host, pool) weighted hit fractions
     lower to one ``[n_hosts * n_pools]`` latency-scale vector consumed by
     every analyzer implementation (numpy oracle, fused inline XLA, Pallas
     cascade) — one kernel body serves cache and no-cache modes, and a
     zero-capacity cache yields the all-ones vector, reproducing the
     no-cache analysis bit-for-bit.

The top-``ways`` ranking gives a useful guarantee: growing capacity (more
ways over fixed sets) retains a superset of lines every epoch, so per-epoch
hit fractions are non-decreasing and simulated latency non-increasing —
regression-locked in ``tests/test_migration_cache.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .events import MemEvents, RegionMap
from .topology import FlatTopology

__all__ = ["DeviceCacheConfig", "DeviceCacheModel"]


@dataclasses.dataclass(frozen=True)
class DeviceCacheConfig:
    """Per-pool expander-side DRAM cache parameters.

    ``ways`` is derived as ``capacity_bytes // (line_bytes * n_sets)``;
    sweeps that vary ``capacity_bytes`` over multiples of
    ``line_bytes * n_sets`` therefore vary associativity at fixed set
    count, which is the monotone axis (see module docstring).
    """

    capacity_bytes: float
    line_bytes: int = 4096  # device caches track page-ish granules
    n_sets: int = 64
    hit_latency_ns: float = 25.0  # on-device DRAM hit, vs pool media latency
    pools: Optional[Tuple[str, ...]] = None  # None => every non-local pool

    def __post_init__(self):
        if self.capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        if self.line_bytes <= 0 or self.n_sets <= 0:
            raise ValueError("line_bytes and n_sets must be positive")

    @property
    def ways(self) -> int:
        return int(self.capacity_bytes // (self.line_bytes * self.n_sets))


def _segment_starts(sorted_keys: np.ndarray):
    """(is_first_of_segment [N] bool, segment_start_index [N]) for a
    key-sorted array — the shared grouping idiom of the cursor and
    tag-array passes."""
    seg_first = np.empty(len(sorted_keys), bool)
    seg_first[:1] = True
    seg_first[1:] = sorted_keys[1:] != sorted_keys[:-1]
    firsts = np.nonzero(seg_first)[0]
    return seg_first, firsts[np.cumsum(seg_first) - 1]


class DeviceCacheModel:
    """Stateful per-pool tag arrays + region cursors; see module docstring.

    ``region_maps`` is one map per host (a single-attach program passes
    ``[regions]``): region ids are per-host, so lines are keyed by the
    (host, region) pair — co-tenants' same-named regions are distinct
    address ranges (private replicas; the coherency model, not the cache,
    owns the shared-object semantics).

    Not thread-safe: ``observe`` mutates cursors and tag state, so callers
    run it on the trace-submitting thread (the attach pipeline's contract
    for every stateful per-epoch transform).
    """

    def __init__(
        self,
        cfg: DeviceCacheConfig,
        flat: FlatTopology,
        region_maps: Sequence[RegionMap],
    ):
        self.cfg = cfg
        self.flat = flat
        if len(region_maps) > flat.n_hosts:
            raise ValueError(
                f"{len(region_maps)} region maps for {flat.n_hosts} host(s)"
            )
        # fewer maps than hosts: a single program attached to a multi-host
        # topology only ever emits events for the hosts it covers, so the
        # remaining hosts get empty address spaces
        region_maps = list(region_maps) + [
            RegionMap() for _ in range(flat.n_hosts - len(region_maps))
        ]
        if cfg.pools is None:
            cached = list(range(1, flat.n_pools))
        else:
            cached = [flat.pool_names.index(n) for n in cfg.pools]
            if 0 in cached:
                raise ValueError("local DRAM has no device-side cache")
        self._cached_pools = tuple(cached)

        # global region id = host offset + per-host rid; contiguous
        # line-aligned address ranges per global region
        self._gid_off = np.zeros((flat.n_hosts,), np.int64)
        sizes: List[float] = []
        for h, rm in enumerate(region_maps):
            self._gid_off[h] = len(sizes)
            sizes.extend(float(r.nbytes) for r in rm)
        line = float(cfg.line_bytes)
        self._sizes = np.maximum(np.asarray(sizes, np.float64), line)
        lines_per = np.ceil(self._sizes / line).astype(np.int64)
        self._base_line = np.concatenate([[0], np.cumsum(lines_per)])[:-1]
        self._cursor = np.zeros((len(sizes),), np.float64)

        # per cached pool: sorted resident-line array (the tag state)
        self._resident: Dict[int, np.ndarray] = {
            p: np.zeros((0,), np.int64) for p in self._cached_pools
        }
        self.access_weight_total = 0.0
        self.hit_weight_total = 0.0

    @property
    def hit_fraction(self) -> float:
        """Running weighted hit fraction across every observed epoch."""
        if self.access_weight_total <= 0:
            return float("nan")
        return self.hit_weight_total / self.access_weight_total

    # ------------------------------------------------------------------ #

    def _event_lines(self, trace: MemEvents) -> np.ndarray:
        """[N] line id per event: streaming region cursors -> wrapped
        offsets -> global line addresses (advances the cursors)."""
        gid = trace.region.astype(np.int64) + self._gid_off[trace.host]
        order = np.argsort(gid, kind="stable")  # events stay in time order per gid
        gs, bs = gid[order], trace.bytes_[order]
        excl = np.cumsum(bs) - bs
        _, seg_start = _segment_starts(gs)
        within = excl - excl[seg_start]
        off_sorted = np.mod(self._cursor[gs] + within, self._sizes[gs])
        self._cursor += np.bincount(gid, weights=trace.bytes_, minlength=len(self._cursor))
        off = np.empty_like(off_sorted)
        off[order] = off_sorted
        return self._base_line[gid] + (off // self.cfg.line_bytes).astype(np.int64)

    def _update_pool(
        self, lines: np.ndarray, weight: np.ndarray, p: int
    ) -> np.ndarray:
        """One pool's epoch tag update; returns the per-event hit mask."""
        W, n_sets = self.cfg.ways, self.cfg.n_sets
        old = self._resident[p]
        if W == 0:
            return np.zeros(len(lines), bool)
        uniq, first_idx = np.unique(lines, return_index=True)
        counts = np.bincount(
            np.searchsorted(uniq, lines), weights=weight, minlength=len(uniq)
        )
        keep_old = old[~np.isin(old, uniq)]  # untouched residents keep spare ways
        cand = np.concatenate([uniq, keep_old])
        ccnt = np.concatenate([counts, np.zeros(len(keep_old))])
        cset = cand % n_sets
        order = np.lexsort((cand, -ccnt, cset))  # by set, hottest first
        _, seg_start = _segment_starts(cset[order])
        rank = np.arange(len(cand)) - seg_start
        resident = np.sort(cand[order][rank < W])

        first_mask = np.zeros(len(lines), bool)
        first_mask[first_idx] = True
        hit = np.isin(lines, resident) & (np.isin(lines, old) | ~first_mask)
        self._resident[p] = resident
        return hit

    def observe(self, trace: MemEvents) -> np.ndarray:
        """Simulate one epoch; returns [H, P] weighted hit fractions
        (0 where a (host, pool) cell saw no traffic or has no cache)."""
        H, P = self.flat.n_hosts, self.flat.n_pools
        frac = np.zeros((H, P), np.float64)
        if trace.n == 0:
            return frac
        lines = self._event_lines(trace)
        hit = np.zeros(trace.n, bool)
        for p in self._cached_pools:
            m = trace.pool == p
            if m.any():
                hit[m] = self._update_pool(lines[m], trace.weight[m], p)
        vp = trace.host.astype(np.int64) * P + trace.pool
        hw = np.bincount(vp, weights=trace.weight * hit, minlength=H * P)
        tw = np.bincount(vp, weights=trace.weight, minlength=H * P)
        np.divide(hw, tw, out=frac.reshape(-1), where=tw > 0)
        self.hit_weight_total += float(hw.sum())
        self.access_weight_total += float(
            tw.reshape(H, P)[:, list(self._cached_pools)].sum()
        ) if self._cached_pools else 0.0
        return frac

    def latency_scale(self, hit_frac: np.ndarray) -> np.ndarray:
        """Lower [H, P] hit fractions to the analyzer's [H*P] scale vector.

        A hit saves ``media_latency - hit_latency`` (clipped so the scaled
        added latency stays non-negative); a zero fraction yields exactly
        1.0, so no-cache and capacity-0 analyses are bitwise identical.
        """
        flat = self.flat
        added = np.maximum(flat.pool_latency_ns - flat.local_latency_ns, 0.0)
        saved = np.zeros((flat.n_pools,), np.float64)
        cp = list(self._cached_pools)
        saved[cp] = np.clip(
            flat.pool_media_latency_ns[cp] - self.cfg.hit_latency_ns, 0.0, None
        )
        saved_v = np.minimum(np.tile(saved, flat.n_hosts), added)
        scale = np.ones_like(added)
        nz = added > 0
        scale[nz] = 1.0 - hit_frac.reshape(-1)[nz] * saved_v[nz] / added[nz]
        return scale

    def observe_scale(self, trace: MemEvents) -> Optional[np.ndarray]:
        """``observe`` + ``latency_scale`` in one call; returns None for a
        hit-free epoch (callers then skip the scale row entirely)."""
        frac = self.observe(trace)
        if not frac.any():
            return None
        return self.latency_scale(frac)
