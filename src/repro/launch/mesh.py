"""Production mesh definition (see brief: MULTI-POD DRY-RUN step 1)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    A FUNCTION (not module-level state) so importing this module never
    touches jax device state; callers control XLA_FLAGS first.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))
