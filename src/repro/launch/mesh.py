"""Production mesh definition (see brief: MULTI-POD DRY-RUN step 1)."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax

__all__ = [
    "make_abstract_mesh",
    "make_production_mesh",
    "make_local_mesh",
    "make_data_mesh",
]


def make_abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Version-compatible ``AbstractMesh`` constructor.

    Newer JAX takes ``AbstractMesh(shape, axis_names)``; JAX <= 0.4.x takes
    a single tuple of ``(name, size)`` pairs.  Try the modern signature
    first and fall back on the TypeError the legacy one raises for it.
    """
    from jax.sharding import AbstractMesh

    shape_t: Tuple[int, ...] = tuple(int(s) for s in shape)
    axes_t: Tuple[str, ...] = tuple(axes)
    if len(shape_t) != len(axes_t):
        raise ValueError(f"shape {shape_t} / axes {axes_t} length mismatch")
    try:
        return AbstractMesh(shape_t, axes_t)
    except TypeError:
        return AbstractMesh(tuple(zip(axes_t, shape_t)))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    A FUNCTION (not module-level state) so importing this module never
    touches jax device state; callers control XLA_FLAGS first.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))


def make_data_mesh(n: int | None = None):
    """1-D ``('data',)`` mesh over the first ``n`` (default: all) devices.

    The mesh shape the analyzer's sharded dispatch expects: stacked
    ``[K, B, N]`` dispatches shard their leading scenario/session/rack axis
    over 'data' (see ``repro.distributed.sharding.resolve_data_mesh``).
    Built with ``jax.sharding.Mesh`` directly so a subset of devices works
    on every supported JAX version.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = len(devs) if n is None else max(1, min(int(n), len(devs)))
    return Mesh(np.array(devs[:n]), ("data",))
