import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each runnable cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. jits the right step (train_step / prefill / decode) with full
     in/out shardings from :mod:`repro.distributed.sharding`,
  3. ``.lower(**ShapeDtypeStructs)`` then ``.compile()`` — proving the
     sharding config is coherent end to end with zero allocation,
  4. records ``memory_analysis()`` / ``cost_analysis()`` and the collective
     bytes parsed from the compiled HLO into a JSON report that
     §Roofline and the benchmarks read.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen3-0.6b
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both   # all cells
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro import configs as cfgs
from repro.core.roofline import collective_bytes_from_hlo, roofline_terms
from repro.distributed import sharding as shr
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    abstract_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.optim.adamw import AdamWConfig

# archs that need ZeRO-3-style parameter sharding to fit 16 GB/chip
FSDP_ARCHS = {
    "mistral-large-123b",
    "llama4-maverick-400b-a17b",
    "jamba-v0.1-52b",
    "qwen2-vl-72b",
}

DEFAULT_OUT = "benchmarks/dryrun_results.json"


def strategy_for(arch: str, override: Optional[str] = None, kind: str = "train") -> str:
    """FSDP only where there is training state to shard.  §Perf cell 2
    showed FSDP params on serving steps convert weight gathers into
    activation partial-sums (−75% collective when fixed), so serving
    defaults to TP-only."""
    if override:
        return override
    if kind == "prefill":
        # compute-heavy serving: TP-only (measured −75% collective, §Perf)
        return "dp_tp"
    # train: FSDP shards optimizer state; decode: weight READS dominate, so
    # param sharding wins (measured: dp regresses decode 2-5x) — keep default
    return "fsdp_tp" if arch in FSDP_ARCHS else "dp_tp"


def _mem_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def _compile_cell(cfg, shape, mesh, strat, opt_cfg, donate, compress_grads):
    """jit+lower+compile one step; returns (compiled, timings)."""
    from repro.models import Model

    inputs = cfgs.input_specs(cfg, shape)
    in_sh_inputs = shr.tree_named(mesh, shr.input_pspecs(inputs, mesh))
    pshapes = jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))
    pspecs = shr.param_pspecs(pshapes, cfg, mesh, strat)
    p_sh = shr.tree_named(mesh, pspecs)

    block_specs = None
    if cfg.fsdp_gather_at_layer:
        # TP-only specs for one group (ZeRO-3 gather-at-use constraint)
        from jax.sharding import PartitionSpec as P

        tp = shr.param_pspecs(pshapes, cfg, mesh, "dp_tp")["blocks"]
        if isinstance(tp, list):
            block_specs = tp[0]  # unrolled: already per-group (no lead dim)
        else:
            block_specs = jax.tree.map(
                lambda sp: P(*tuple(sp)[1:]),
                tp,
                is_leaf=lambda v: isinstance(v, P),
            )

    t0 = time.time()
    if shape.kind == "train":
        step = make_train_step(
            cfg, opt_cfg, compress_grads=compress_grads, block_specs=block_specs
        )
        params, opt = abstract_train_state(cfg, opt_cfg, compress_grads)
        opt_sh = {
            "adam": {
                "mu": p_sh,
                "nu": p_sh,
                "step": shr.named(mesh, jax.sharding.PartitionSpec()),
            },
            "ef": p_sh if compress_grads else {},
        }
        fn = jax.jit(
            step,
            in_shardings=(p_sh, opt_sh, in_sh_inputs),
            out_shardings=(p_sh, opt_sh, None),
            donate_argnums=(0, 1) if donate else (),
        )
        with mesh:
            lowered = fn.lower(params, opt, inputs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, pad_to=shape.seq_len)
        fn = jax.jit(step, in_shardings=(p_sh, in_sh_inputs))
        with mesh:
            lowered = fn.lower(pshapes, inputs)
    else:  # decode
        step = make_decode_step(cfg)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, in_sh_inputs),
            donate_argnums=(1,) if donate else (),
        )
        with mesh:
            lowered = fn.lower(pshapes, inputs)
    lower_s = time.time() - t0
    t1 = time.time()
    with mesh:
        compiled = lowered.compile()
    return compiled, {"lower_s": lower_s, "compile_s": time.time() - t1}


def _extract_costs(compiled, group_size_hint: int = 1) -> Dict[str, Any]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll,
        "hlo_len": len(hlo),
    }


def run_cell(
    arch: str,
    shape_name: str,
    mesh,
    mesh_name: str,
    strategy: Optional[str] = None,
    donate: bool = True,
    compress_grads: bool = False,
    moe_dispatch: Optional[str] = None,
    remat_policy: Optional[str] = None,
    cfg_override=None,
) -> Dict[str, Any]:
    """Lower+compile one cell; returns the record for the JSON report.

    XLA's cost analysis counts a while-loop (lax.scan) body ONCE, so the
    full-depth scanned module's costs are depth-independent (verified
    empirically: flops constant in n_groups).  We therefore compile the cell
    three times: full depth *scanned* (the sharding/memory proof +
    memory_analysis) plus UNROLLED 2-group and 4-group reductions whose costs
    do scale with depth; per-group cost = (c4 − c2)/2 and
    total = c2 + (n_groups − 2)·(c4 − c2)/2, exact for a homogeneous stack.
    """
    shape = cfgs.SHAPES[shape_name]
    cfg = cfgs.get_config(arch, shape_name)
    if cfg_override is not None:
        cfg = cfg_override
    if moe_dispatch:
        cfg = dataclasses.replace(cfg, moe_dispatch=moe_dispatch)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy_name=remat_policy)
    strat = strategy_for(arch, strategy, kind=shape.kind)
    opt_cfg = AdamWConfig()
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "strategy": strat,
        "kind": shape.kind,
        "n_layers": cfg.n_layers,
        "n_groups": cfg.n_groups,
    }

    with jax.default_device(jax.devices("cpu")[0]):
        # 1) full-depth compile: the dry-run proof + memory analysis
        compiled, times = _compile_cell(
            cfg, shape, mesh, strat, opt_cfg, donate, compress_grads
        )
        rec.update(times)
        rec["memory_analysis"] = _mem_analysis_dict(compiled)

        # 2) depth extrapolation for scan-aware costs (unrolled reductions)
        G = cfg.n_groups
        gs = cfg.group_size
        if cfg.scan_layers and G > 2:
            cfg2 = dataclasses.replace(cfg, n_layers=2 * gs, scan_layers=False)
            cfg4 = dataclasses.replace(cfg, n_layers=4 * gs, scan_layers=False)
            comp2, _ = _compile_cell(cfg2, shape, mesh, strat, opt_cfg, donate, compress_grads)
            comp4, _ = _compile_cell(cfg4, shape, mesh, strat, opt_cfg, donate, compress_grads)
            c2, c4 = _extract_costs(comp2), _extract_costs(comp4)
            slope = lambda a, b: (b - a) / 2.0
            flops = c2["flops"] + (G - 2) * slope(c2["flops"], c4["flops"])
            nbytes = c2["bytes"] + (G - 2) * slope(c2["bytes"], c4["bytes"])
            coll = {
                k: c2["coll"].get(k, 0.0)
                + (G - 2) * slope(c2["coll"].get(k, 0.0), c4["coll"].get(k, 0.0))
                for k in c4["coll"]
            }
            rec["cost_method"] = "unrolled_depth_extrapolation"
            rec["hlo_len"] = c4["hlo_len"]
        else:
            c = _extract_costs(compiled)
            flops, nbytes, coll = c["flops"], c["bytes"], c["coll"]
            rec["cost_method"] = "direct"
            rec["hlo_len"] = c["hlo_len"]

        rec["hlo_flops"] = flops
        rec["hlo_bytes"] = nbytes
        rec["collectives"] = coll

        n_chips = mesh.devices.size
        mf = cfg.model_flops(shape.kind, shape.global_batch, shape.seq_len)
        rec["model_flops_global"] = mf
        rec["model_flops_per_chip"] = mf / n_chips
        terms = roofline_terms(
            hlo_flops=flops,
            hlo_bytes=nbytes,
            collective_bytes=coll["total"],
            model_flops=mf / n_chips,
            n_chips=n_chips,
        )
        rec["roofline"] = terms.as_dict()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("1pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod_2x16x16", make_production_mesh(multi_pod=True)))

    cells = [
        c
        for c in cfgs.cells()
        if c["runnable"]
        and (args.arch is None or c["arch"] == args.arch)
        and (args.shape is None or c["shape"] == args.shape)
    ]

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}
    for mesh_name, mesh in meshes:
        for cell in cells:
            key = (cell["arch"], cell["shape"], mesh_name)
            if key in done:
                continue
            label = f"{cell['arch']} × {cell['shape']} × {mesh_name}"
            print(f"[dryrun] {label} ...", flush=True)
            try:
                rec = run_cell(
                    cell["arch"], cell["shape"], mesh, mesh_name,
                    strategy=args.strategy,
                )
                r = rec["roofline"]
                print(
                    f"  ok  compile={rec['compile_s']:.1f}s  "
                    f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                    f"collective={r['collective_s']:.4f}s dominant={r['dominant']}",
                    flush=True,
                )
            except Exception as e:
                rec = {
                    "arch": cell["arch"], "shape": cell["shape"], "mesh": mesh_name,
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                print(f"  FAIL {type(e).__name__}: {e}", flush=True)
            results.append(rec)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if "error" not in r)
    print(f"[dryrun] {n_ok}/{len(results)} cells compiled; report -> {args.out}")


if __name__ == "__main__":
    main()
