"""Step-function builders shared by the trainer, server, and dry-run."""

from __future__ import annotations

from typing import Optional

import jax

from repro.models import Model, ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import ef_compress, init_error_state

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "abstract_train_state",
]


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    compress_grads: bool = False,
    block_specs=None,
):
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``compress_grads`` the gradient tree passes through int8
    error-feedback quantization before the optimizer (the DP all-reduce then
    moves int8); the error residual rides inside opt_state['ef'].
    """
    model = Model(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            if cfg.cast_params_at_step:
                p = jax.tree.map(
                    lambda x: x.astype(cfg.dtype) if x.ndim >= 2 else x, p
                )
            return model.loss(p, batch, block_specs=block_specs)

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if compress_grads:
            grads, new_err = ef_compress(grads, opt_state["ef"])
        new_params, new_inner, om = adamw_update(
            params, grads, opt_state["adam"], opt_cfg
        )
        new_opt = {"adam": new_inner}
        if compress_grads:
            new_opt["ef"] = new_err
        else:
            new_opt["ef"] = opt_state["ef"]
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_opt, metrics

    return train_step


def abstract_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, compress_grads: bool = False):
    """ShapeDtypeStruct pytrees for (params, opt_state) — no allocation."""
    model = Model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    adam = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
    ef = jax.eval_shape(lambda p: init_error_state(p), params) if compress_grads else {}
    return params, {"adam": adam, "ef": ef}


def _maybe_cast(cfg, params):
    if cfg.cast_params_at_step:
        return jax.tree.map(
            lambda x: x.astype(cfg.dtype) if x.ndim >= 2 else x, params
        )
    return params


def make_prefill_step(cfg: ModelConfig, pad_to: Optional[int] = None):
    model = Model(cfg)

    def prefill_step(params, batch):
        params = _maybe_cast(cfg, params)
        inp = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
        logits, caches, cache_len = model.prefill(params, inp, pad_to=pad_to)
        return logits, caches, cache_len

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    model = Model(cfg)

    def decode_step(params, state):
        params = _maybe_cast(cfg, params)
        tok = state["token"] if cfg.embed_inputs else state["embed"]
        logits, new_caches = model.decode_step(
            params, state["caches"], tok, state["cache_len"]
        )
        return logits, new_caches, state["cache_len"] + 1

    return decode_step
