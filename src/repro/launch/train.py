"""Real training driver (CPU-scale; the same code path the pods would run).

Composes: model zoo + AdamW + synthetic pipeline + checkpoint manager +
CXLMemSim attach.  Used by ``examples/train_100m.py`` and the integration
tests; on real hardware the only change is the mesh and the device count.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 20 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.checkpoint.manager import CheckpointManager, FaultToleranceConfig
from repro.core import (
    CXLMemSim,
    ClassMapPolicy,
    EpochSchedule,
    two_tier_topology,
)
from repro.data.pipeline import SyntheticPipeline
from repro.launch.steps import make_train_step
from repro.models import Model, ModelConfig
from repro.models.phases import build_regions_and_phases
from repro.optim.adamw import AdamWConfig, adamw_init

__all__ = ["train_loop", "main"]


def train_loop(
    cfg: ModelConfig,
    steps: int = 20,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    ckpt_dir: Optional[str] = None,
    ckpt_interval: int = 10,
    simulate: bool = False,
    topology=None,
    policy=None,
    seed: int = 0,
    log_every: int = 5,
) -> Dict[str, Any]:
    opt_cfg = AdamWConfig(lr=lr, total_steps=max(steps, 2), warmup_steps=max(steps // 10, 1))
    model = Model(cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    manager = None
    start_step = 0
    if ckpt_dir:
        manager = CheckpointManager(
            FaultToleranceConfig(directory=ckpt_dir, interval_steps=ckpt_interval)
        )

        def init_fn():
            params = model.init(jax.random.PRNGKey(seed))
            return {"params": params, "opt": {"adam": adamw_init(params, opt_cfg), "ef": {}}}

        state, start_step = manager.resume_or_init(init_fn)
        params, opt_state = state["params"], state["opt"]
    else:
        params = model.init(jax.random.PRNGKey(seed))
        opt_state = {"adam": adamw_init(params, opt_cfg), "ef": {}}

    pipe = SyntheticPipeline(cfg, batch, seq, seed=seed)

    attached = None
    if simulate:
        topology = topology or two_tier_topology()
        policy = policy or ClassMapPolicy({"opt_state": "cxl_pool"})
        regions, phases = build_regions_and_phases(cfg, "train", batch, seq)
        sim = CXLMemSim(topology, policy, epoch=EpochSchedule("step"), check_capacity=False)
        attached = sim.attach(step_fn, phases, regions)

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch_data = pipe.device_batch(step)
        ts = time.time()
        if attached is not None:
            params, opt_state, metrics = attached.step(params, opt_state, batch_data)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        jax.block_until_ready(metrics["loss"])
        dur = time.time() - ts
        losses.append(float(metrics["loss"]))
        if manager is not None:
            manager.observe_step(step, dur)
            manager.maybe_save(
                step, {"params": params, "opt": opt_state}
            )
        if log_every and step % log_every == 0:
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f} "
                f"({dur:.2f}s)",
                flush=True,
            )
    out = {
        "losses": losses,
        "steps": steps - start_step,
        "wall_s": time.time() - t0,
        "final_loss": losses[-1] if losses else float("nan"),
        "start_step": start_step,
    }
    if attached is not None:
        out["sim"] = attached.report.summary()
    if manager is not None:
        out["stragglers"] = manager.straggler_events
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--simulate", action="store_true", help="attach CXLMemSim")
    args = ap.parse_args()
    cfg = cfgs.get_smoke(args.arch) if args.smoke else cfgs.get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)  # CPU-friendly
    out = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
        ckpt_dir=args.ckpt_dir, simulate=args.simulate,
    )
    print({k: v for k, v in out.items() if k != "losses"})


if __name__ == "__main__":
    main()
