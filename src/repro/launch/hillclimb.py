"""§Perf hillclimb driver: run named variants of a dry-run cell and compare
their roofline terms.

Each variant = {strategy | compress_grads | any ModelConfig field overrides}.
Results append to benchmarks/hillclimb_results.json; EXPERIMENTS.md §Perf
narrates the hypothesis → change → before/after → verdict log.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell granite-moe-3b-a800m:train_4k \
        --variant baseline --variant compress_grads

For CXL placement/topology hillclimbs, use the batched
:meth:`repro.core.ScenarioSuite.successive_halving` instead (one stacked
device dispatch per round; see ``examples/topology_explorer.py``).
"""

# NOTE: the XLA_FLAGS mutation must come AFTER the docstring (a statement
# before it would make __doc__ None and empty `-m` help) but BEFORE any jax
# import, so the host platform exposes enough virtual devices for the mesh.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
from typing import Any, Dict

import jax.numpy as jnp

import repro.configs as cfgs
from repro.launch.dryrun import run_cell, strategy_for
from repro.launch.mesh import make_production_mesh

OUT = "benchmarks/hillclimb_results.json"

# named variants: (strategy_override, compress_grads, cfg field overrides)
VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    "fsdp": {"strategy": "fsdp_tp"},
    "dp": {"strategy": "dp_tp"},
    "compress_grads": {"compress_grads": True},
    "cast_bf16": {"cfg": {"cast_params_at_step": True}},
    "cast_bf16+compress": {"compress_grads": True, "cfg": {"cast_params_at_step": True}},
    "remat_dots": {"cfg": {"remat_policy_name": "dots"}},
    "no_remat": {"cfg": {"remat": False}},
    "moe_dense_dispatch": {"cfg": {"moe_dispatch": "dense"}},
    "moe_dp": {"strategy": "fsdp_tp+moe_dp"},
    "gqa_fix": {"strategy_suffix": "+gqa_fix"},
    "gqa_fix+cast": {"strategy_suffix": "+gqa_fix", "cfg": {"cast_params_at_step": True}},
    "gqa_fix+cast+compress": {"strategy_suffix": "+gqa_fix", "compress_grads": True,
                              "cfg": {"cast_params_at_step": True}},
    "moe_dp+gqa_fix+cast": {"strategy": "fsdp_tp+moe_dp+gqa_fix",
                            "cfg": {"cast_params_at_step": True}},
    "dp+gqa_fix+cast": {"strategy": "dp_tp+gqa_fix", "cfg": {"cast_params_at_step": True}},
    "moe_dp+cast": {"strategy": "fsdp_tp+moe_dp", "cfg": {"cast_params_at_step": True}},
    "moe_groups_8k": {"cfg": {"moe_group_tokens": 8192}},
    "moe_groups_2k": {"cfg": {"moe_group_tokens": 2048}},
    "moe_cap_1.0": {"cfg": {"capacity_factor": 1.0}},
    "kv_f8": {"cfg": {"cache_dtype": jnp.float8_e4m3fn}},
    "kv_bf16": {"cfg": {"cache_dtype": jnp.bfloat16}},
    "attn_blocks_2k": {"cfg": {"attn_block_q": 2048, "attn_block_k": 2048}},
    "ssm_chunk_256": {"cfg": {"ssm_chunk": 256}},
    "pad_vocab": {"cfg": {"pad_vocab_to_multiple": 16}},
    "moe_dp+pad_vocab": {"strategy": "fsdp_tp+moe_dp", "cfg": {"pad_vocab_to_multiple": 16}},
    "moe_dp_dp+pad_vocab": {"strategy": "dp_tp+moe_dp", "cfg": {"pad_vocab_to_multiple": 16}},
    "moe_dp+pad+cap1+g2k": {"strategy": "fsdp_tp+moe_dp",
        "cfg": {"pad_vocab_to_multiple": 16, "capacity_factor": 1.0, "moe_group_tokens": 2048}},
    "gqa_fix+pad_vocab": {"strategy_suffix": "+gqa_fix", "cfg": {"pad_vocab_to_multiple": 16}},
    "best_granite": {"strategy": "dp_tp+moe_dp",
        "cfg": {"pad_vocab_to_multiple": 16, "moe_dispatch": "scatter"}},
    "best_granite+cap1": {"strategy": "dp_tp+moe_dp",
        "cfg": {"pad_vocab_to_multiple": 16, "moe_dispatch": "scatter", "capacity_factor": 1.0}},
    "scatter": {"cfg": {"moe_dispatch": "scatter"}},
    "zero3_gather": {"strategy": "fsdp_tp", "cfg": {"fsdp_gather_at_layer": True}},
    "zero3_gather+dots": {"strategy": "fsdp_tp",
        "cfg": {"fsdp_gather_at_layer": True, "remat_policy_name": "dots"}},
    "ep_data": {"strategy": "fsdp_tp+ep_data"},
    "ep_data_dp": {"strategy": "dp_tp+ep_data"},
    "no_remat_fsdp": {"strategy": "fsdp_tp", "cfg": {"remat": False}},
    "llama4_best": {"strategy": "fsdp_tp",
        "cfg": {"remat": False, "moe_group_tokens": 2048}},
    "granite_best": {"strategy": "dp_tp+moe_dp",
        "cfg": {"pad_vocab_to_multiple": 16, "remat": False}},
}


def run_variant(arch: str, shape: str, vname: str, mesh, mesh_name: str) -> Dict:
    spec = VARIANTS[vname]
    cfg = cfgs.get_config(arch, shape)
    if spec.get("cfg"):
        cfg = dataclasses.replace(cfg, **spec["cfg"])
    strategy = spec.get("strategy")
    if spec.get("strategy_suffix"):
        strategy = strategy_for(arch, strategy) + spec["strategy_suffix"]
    rec = run_cell(
        arch, shape, mesh, mesh_name,
        strategy=strategy,
        compress_grads=spec.get("compress_grads", False),
        cfg_override=cfg,
    )
    rec["variant"] = vname
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", action="append", required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    mesh_name = "1pod_16x16" if args.mesh == "single" else "2pod_2x16x16"
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for vname in args.variant:
        print(f"[hillclimb] {arch} × {shape} × {vname} ...", flush=True)
        try:
            rec = run_variant(arch, shape, vname, mesh, mesh_name)
            r = rec["roofline"]
            print(
                f"  compute={r['compute_s']:.4f} memory={r['memory_s']:.4f} "
                f"collective={r['collective_s']:.4f} dominant={r['dominant']} "
                f"bound={r['bound_s']:.4f} frac={r['roofline_fraction']:.4f}",
                flush=True,
            )
        except Exception as e:
            import traceback

            rec = {
                "arch": arch, "shape": shape, "mesh": mesh_name, "variant": vname,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-1500:],
            }
            print(f"  FAIL {rec['error']}", flush=True)
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
